//! BLAS interface tests (§IV-B, Lst. 2): indexing-function GEMM / SYRK over
//! column-major storage, verified against the softfloat baseline.

use apfp::baseline;
use apfp::blas::{self, BlasTrans};
use apfp::config::ApfpConfig;
use apfp::coordinator::{Device, Matrix};
use apfp::softfloat::ApFloat;

fn device() -> Option<Device> {
    let dir = apfp::runtime::default_artifact_dir();
    let cfg = ApfpConfig { compute_units: 2, ..Default::default() };
    let native = cfg.backend == apfp::runtime::BackendKind::Native;
    match Device::new(cfg, &dir) {
        Ok(dev) => Some(dev),
        // the xla backend legitimately skips without artifacts; the default
        // native backend must run these tests on every checkout
        Err(e) if !native => {
            eprintln!("skipped: {e:#}");
            None
        }
        Err(e) => panic!("native device must open on a clean checkout: {e:#}"),
    }
}

/// Column-major buffer like Elemental's LockedBuffer view.
struct ColMajor {
    data: Vec<ApFloat>,
    ld: usize,
}

impl ColMajor {
    fn from_matrix(m: &Matrix) -> Self {
        let ld = m.rows();
        let mut data = vec![ApFloat::zero(m.prec()); ld * m.cols()];
        for i in 0..m.rows() {
            for j in 0..m.cols() {
                data[j * ld + i] = m.get(i, j).clone();
            }
        }
        ColMajor { data, ld }
    }

    fn to_matrix(&self, rows: usize, cols: usize, prec: u32) -> Matrix {
        Matrix::from_fn(rows, cols, prec, |i, j| self.data[j * self.ld + i].clone())
    }
}

#[test]
fn gemm_normal_normal_matches_reference() {
    let Some(dev) = device() else { return };
    let (m, n, k) = (11, 13, 9);
    let a = Matrix::random(m, k, 448, 70, 30);
    let b = Matrix::random(k, n, 448, 71, 30);
    let c = Matrix::random(m, n, 448, 72, 30);
    let (ca, cb) = (ColMajor::from_matrix(&a), ColMajor::from_matrix(&b));
    let mut cc = ColMajor::from_matrix(&c);

    // Lst. 2 style: closures indexing the caller's own storage
    let out_ref = std::cell::RefCell::new(vec![ApFloat::zero(448); cc.data.len()]);
    blas::gemm(
        &dev,
        BlasTrans::Normal,
        BlasTrans::Normal,
        m, n, k,
        |i| ca.data[i].clone(), ca.ld,
        |i| cb.data[i].clone(), cb.ld,
        |i| cc.data[i].clone(),
        |i, v| out_ref.borrow_mut()[i] = v,
        cc.ld,
    )
    .unwrap();
    cc.data = out_ref.into_inner();

    let got = cc.to_matrix(m, n, 448);
    let want = baseline::gemm_serial(&a, &b, &c);
    assert_eq!(got, want);
}

#[test]
fn gemm_transpose_b() {
    let Some(dev) = device() else { return };
    let (m, n, k) = (6, 5, 7);
    let a = Matrix::random(m, k, 448, 80, 30);
    let bt = Matrix::random(n, k, 448, 81, 30); // we pass B^T storage
    let c = Matrix::zeros(m, n, 448);
    let (ca, cbt) = (ColMajor::from_matrix(&a), ColMajor::from_matrix(&bt));
    let cc = ColMajor::from_matrix(&c);

    let out_ref = std::cell::RefCell::new(cc.data.clone());
    blas::gemm(
        &dev,
        BlasTrans::Normal,
        BlasTrans::Transpose,
        m, n, k,
        |i| ca.data[i].clone(), ca.ld,
        |i| cbt.data[i].clone(), cbt.ld,
        |_| ApFloat::zero(448),
        |i, v| out_ref.borrow_mut()[i] = v,
        cc.ld,
    )
    .unwrap();

    // reference: B = bt^T
    let b = Matrix::from_fn(k, n, 448, |i, j| bt.get(j, i).clone());
    let want = baseline::gemm_serial(&a, &b, &c);
    let got = ColMajor { data: out_ref.into_inner(), ld: cc.ld }.to_matrix(m, n, 448);
    assert_eq!(got, want);
}

#[test]
fn syrk_lower_triangle() {
    let Some(dev) = device() else { return };
    let (m, k) = (8, 5);
    let a = Matrix::random(m, k, 448, 90, 30);
    let ca = ColMajor::from_matrix(&a);
    let c0 = Matrix::zeros(m, m, 448);
    let cc = ColMajor::from_matrix(&c0);

    let out_ref = std::cell::RefCell::new(cc.data.clone());
    blas::syrk(
        &dev,
        m, k,
        |i| ca.data[i].clone(), ca.ld,
        |_| ApFloat::zero(448),
        |i, v| out_ref.borrow_mut()[i] = v,
        m,
    )
    .unwrap();
    let got = ColMajor { data: out_ref.into_inner(), ld: m }.to_matrix(m, m, 448);

    // reference: full A * A^T
    let at = Matrix::from_fn(k, m, 448, |i, j| a.get(j, i).clone());
    let want = baseline::gemm_serial(&a, &at, &c0);
    for i in 0..m {
        for j in 0..m {
            if i >= j {
                assert_eq!(got.get(i, j), want.get(i, j), "lower ({i},{j})");
            } else {
                assert!(got.get(i, j).is_zero(), "upper ({i},{j}) must be untouched");
            }
        }
    }
}

#[test]
fn linalg_backend_device_matches_host() {
    // MatmulBackend::Device must be bit-identical to MatmulBackend::Host —
    // the guarantee the SDP example's drop-in relies on.
    use apfp::linalg::MatmulBackend;
    let Some(dev) = device() else { return };
    let a = Matrix::random(9, 7, 448, 95, 25);
    let b = Matrix::random(7, 8, 448, 96, 25);
    let c = Matrix::random(9, 8, 448, 97, 25);
    let host = MatmulBackend::Host { threads: 2 }.gemm(&a, &b, &c).unwrap();
    let devr = MatmulBackend::Device(&dev).gemm(&a, &b, &c).unwrap();
    assert_eq!(host, devr);
}
