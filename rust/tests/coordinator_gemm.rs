//! End-to-end coordinator test: multi-CU GEMM through the pluggable
//! backend, bit-compared against the software baseline (the paper's
//! verification methodology: accelerator output vs MPFR software
//! computation).
//!
//! On the default native backend the full device stack — scheduler
//! partition, bounded worker queues, tile K-accumulation, metrics — runs
//! on every checkout, with no `artifacts/` directory.  `APFP_BACKEND=xla`
//! drives the same tests through PJRT artifacts instead (skipping when
//! that runtime cannot come up).

use apfp::baseline;
use apfp::config::ApfpConfig;
use apfp::coordinator::{Device, Matrix};
use apfp::runtime::BackendKind;

fn open_device(cfg: ApfpConfig) -> Option<Device> {
    let dir = apfp::runtime::default_artifact_dir();
    let must_open = matches!(cfg.backend, BackendKind::Native | BackendKind::Sim);
    match Device::new(cfg, &dir) {
        Ok(dev) => Some(dev),
        // the xla backend legitimately skips without artifacts; the native
        // and sim backends must come up on every checkout (both serve the
        // builtin manifest) — a failure there is a real regression, never
        // a skip
        Err(e) if !must_open => {
            eprintln!("skipped: {e:#}");
            None
        }
        Err(e) => panic!("builtin-manifest backend must open on a clean checkout: {e:#}"),
    }
}

fn device(cus: usize, bits: u32) -> Option<Device> {
    let cfg = ApfpConfig {
        compute_units: cus,
        bits,
        tile_n: 16,
        tile_m: 16,
        tile_k: 16,
        ..Default::default()
    };
    open_device(cfg)
}

/// Like [`device`], but honoring the environment's tile shape
/// (`APFP_TILE_N/M/K`) so the CI tile-shape matrix genuinely varies the
/// geometry the launch-hazard tests run at.
fn device_env_tiles(cus: usize, bits: u32) -> Option<Device> {
    open_device(ApfpConfig { compute_units: cus, bits, ..Default::default() })
}

#[test]
fn gemm_single_cu_bit_exact() {
    let Some(dev) = device(1, 512) else { return };
    let a = Matrix::random(24, 20, 448, 10, 40);
    let b = Matrix::random(20, 28, 448, 11, 40);
    let c = Matrix::random(24, 28, 448, 12, 40);
    let (got, stats) = dev.gemm(&a, &b, &c).unwrap();
    let want = baseline::gemm_serial(&a, &b, &c);
    assert_eq!(got, want, "device GEMM must be bit-identical to softfloat");
    assert!(stats.tiles > 0 && stats.artifact_calls >= stats.tiles);
}

#[test]
fn gemm_multi_cu_bit_exact_and_partitioned() {
    let Some(dev) = device(3, 512) else { return };
    // deliberately awkward sizes: not multiples of the tile or CU count,
    // so band ends fall mid-tile (the clipped-tile write path)
    let a = Matrix::random(37, 19, 448, 20, 40);
    let b = Matrix::random(19, 23, 448, 21, 40);
    let c = Matrix::random(37, 23, 448, 22, 40);
    let (got, stats) = dev.gemm(&a, &b, &c).unwrap();
    let want = baseline::gemm_serial(&a, &b, &c);
    assert_eq!(got, want);
    assert_eq!(dev.placements().len(), 3);
    assert!(stats.macs > 0);
}

#[test]
fn gemm_repeated_calls_accumulate_and_reuse_the_backend() {
    let Some(dev) = device(2, 512) else { return };
    let a = Matrix::random(16, 16, 448, 30, 20);
    let b = Matrix::random(16, 16, 448, 31, 20);
    let c0 = Matrix::zeros(16, 16, 448);
    let t0 = std::time::Instant::now();
    let (c1, _) = dev.gemm(&a, &b, &c0).unwrap();
    let first = t0.elapsed();
    let t1 = std::time::Instant::now();
    let (c2, _) = dev.gemm(&a, &b, &c1).unwrap();
    let second = t1.elapsed();
    // C accumulates (beta = 1): second call adds A*B again
    let want = baseline::gemm_serial(&a, &b, &c1);
    assert_eq!(c2, want);
    // On the xla path the compile happens once, so the second call must be
    // much faster.  (Native has nothing to compile; both calls are warm
    // and the timing comparison would be noise.)
    if dev.config().backend == BackendKind::Xla {
        assert!(second < first, "no executable reuse: {first:?} -> {second:?}");
    }
}

#[test]
fn stream_ops_through_device() {
    let Some(dev) = device(2, 512) else { return };
    let a = Matrix::random(1, 90, 448, 40, 100);
    let b = Matrix::random(1, 90, 448, 41, 100);
    let c = Matrix::random(1, 90, 448, 42, 100);
    let got = dev.mul_stream(a.values(), b.values()).unwrap();
    for (i, g) in got.iter().enumerate() {
        assert_eq!(*g, a.values()[i].mul(&b.values()[i]), "mul lane {i}");
    }
    let got = dev.add_stream(a.values(), b.values()).unwrap();
    for (i, g) in got.iter().enumerate() {
        assert_eq!(*g, a.values()[i].add(&b.values()[i]), "add lane {i}");
    }
    let got = dev.mac_stream(c.values(), a.values(), b.values()).unwrap();
    for (i, g) in got.iter().enumerate() {
        assert_eq!(*g, c.values()[i].mac(&a.values()[i], &b.values()[i]), "mac lane {i}");
    }
}

#[test]
fn gemm_1024_bits() {
    let Some(dev) = device(2, 1024) else { return };
    let a = Matrix::random(10, 9, 960, 50, 40);
    let b = Matrix::random(9, 12, 960, 51, 40);
    let c = Matrix::random(10, 12, 960, 52, 40);
    let (got, _) = dev.gemm(&a, &b, &c).unwrap();
    assert_eq!(got, baseline::gemm_serial(&a, &b, &c));
}

#[test]
fn native_device_runs_end_to_end_without_artifacts() {
    // The tentpole acceptance criterion: on a clean checkout with no
    // artifacts/ and no xla crate, the native backend lights up the whole
    // device stack and stays bit-identical to the softfloat baseline.
    let dir = std::env::temp_dir().join("apfp_native_no_artifacts/none");
    let cfg = ApfpConfig {
        backend: BackendKind::Native,
        compute_units: 2,
        ..Default::default()
    };
    let dev = Device::new(cfg, &dir).unwrap();
    let a = Matrix::random(13, 11, 448, 60, 40);
    let b = Matrix::random(11, 17, 448, 61, 40);
    let c = Matrix::random(13, 17, 448, 62, 40);
    let (got, stats) = dev.gemm(&a, &b, &c).unwrap();
    assert_eq!(got, baseline::gemm_serial(&a, &b, &c));
    assert!(stats.tiles > 0 && stats.artifact_calls >= stats.tiles && stats.macs > 0);
    let got = dev.mul_stream(a.row(0), a.row(1)).unwrap();
    for (i, g) in got.iter().enumerate() {
        assert_eq!(*g, a.row(0)[i].mul(&a.row(1)[i]), "mul lane {i}");
    }
}

#[test]
fn device_new_without_manifest_errors_cleanly_on_xla() {
    // The artifact-missing path must stay a clean Err on the xla backend
    // (it cannot run without HLO files), never a panic — and never a
    // silently fabricated manifest.
    let dir = std::env::temp_dir().join("apfp_no_artifacts_here");
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir).unwrap();
    let cfg = ApfpConfig { backend: BackendKind::Xla, ..Default::default() };
    let err = match Device::new(cfg.clone(), &dir) {
        Err(e) => e,
        Ok(_) => panic!("Device::new must fail without a manifest on xla"),
    };
    let msg = format!("{err:#}");
    assert!(msg.contains("manifest"), "error should name the missing manifest: {msg}");

    // a directory that does not exist at all behaves the same way
    let missing = dir.join("definitely/not/created");
    assert!(Device::new(cfg, &missing).is_err());
}

#[test]
fn device_new_rejects_invalid_config_before_touching_artifacts() {
    let bad = ApfpConfig { compute_units: 0, ..Default::default() };
    let dir = std::env::temp_dir().join("apfp_cfg_gate_unused");
    let err = match Device::new(bad, &dir) {
        Err(e) => format!("{e:#}"),
        Ok(_) => panic!("zero compute units must be rejected"),
    };
    // the config gate, not the (also-missing) manifest, must trip first
    assert!(err.contains("compute_units"), "unexpected error: {err}");
    assert!(!err.contains("manifest"), "config must be validated first: {err}");
}

#[test]
fn shape_mismatch_is_error() {
    let Some(dev) = device(1, 512) else { return };
    let a = Matrix::random(4, 5, 448, 60, 10);
    let b = Matrix::random(6, 4, 448, 61, 10); // 5 != 6
    let c = Matrix::zeros(4, 4, 448);
    assert!(dev.gemm(&a, &b, &c).is_err());
    // and through the stream API
    let mut s = dev.stream().unwrap();
    let (ha, hb, hc) = (s.upload(&a), s.upload(&b), s.upload(&c));
    assert!(s.enqueue_gemm(ha, hb, hc).is_err());
}

#[test]
fn config_tiles_shape_the_builtin_manifest_end_to_end() {
    // The acceptance criterion for the tiling tentpole: APFP_TILE_N/M/K
    // (here via the config fields they default) reshape the synthesized
    // artifact, the partition, and the executed tile geometry — with
    // deliberately awkward, non-square, non-divisible shapes — while the
    // result stays bit-identical to the softfloat baseline.  Guaranteed-
    // absent artifact dir: an on-disk manifest's compiled geometry would
    // (correctly) override the config and break the count assertions.
    let dir = std::env::temp_dir().join("apfp_cfg_tiles_no_artifacts/none");
    for (tn, tm, tk) in [(5usize, 3usize, 7usize), (1, 16, 2), (16, 1, 1)] {
        let cfg = ApfpConfig {
            compute_units: 2,
            tile_n: tn,
            tile_m: tm,
            tile_k: tk,
            ..Default::default()
        };
        if cfg.backend != BackendKind::Native {
            return; // geometry reshaping is a builtin-manifest feature
        }
        let dev = Device::new(cfg, &dir).unwrap();
        let a = Matrix::random(17, 13, 448, 300 + tn as u64, 30);
        let b = Matrix::random(13, 11, 448, 301 + tm as u64, 30);
        let c = Matrix::random(17, 11, 448, 302 + tk as u64, 30);
        let (got, stats) = dev.gemm(&a, &b, &c).unwrap();
        assert_eq!(got, baseline::gemm_serial(&a, &b, &c), "tiles {tn}x{tm}x{tk}");
        // the partition really ran at the configured shape: per-band
        // ceil-div tile and K-step counts, not the old fixed 8x8x8
        let band = 17usize.div_ceil(2);
        let (rows0, rows1) = (band, 17 - band);
        let tile_rows = rows0.div_ceil(tn) + rows1.div_ceil(tn);
        let expected_tiles = (tile_rows * 11usize.div_ceil(tm)) as u64;
        let k_steps = 13usize.div_ceil(tk) as u64;
        assert_eq!(stats.tiles, expected_tiles, "tiles {tn}x{tm}x{tk}");
        assert_eq!(stats.artifact_calls, stats.tiles * k_steps, "calls {tn}x{tm}x{tk}");
    }
}

#[test]
fn stream_chains_gemms_without_round_trips() {
    let Some(dev) = device(2, 512) else { return };
    let a = Matrix::random(14, 10, 448, 400, 30);
    let b = Matrix::random(10, 9, 448, 401, 30);
    let c = Matrix::random(14, 9, 448, 402, 30);
    let d = Matrix::random(9, 12, 448, 403, 30);
    let e = Matrix::zeros(14, 12, 448);

    let mut s = dev.stream().unwrap();
    let (ha, hb, hc) = (s.upload(&a), s.upload(&b), s.upload(&c));
    let (hd, he) = (s.upload(&d), s.upload(&e));
    s.enqueue_gemm(ha, hb, hc).unwrap(); // C += A @ B
    s.enqueue_gemm(hc, hd, he).unwrap(); // E += (updated C) @ D — C never left
    s.wait().unwrap();

    let c1 = baseline::gemm_serial(&a, &b, &c);
    let want = baseline::gemm_serial(&c1, &d, &e);
    assert_eq!(s.download(hc).unwrap(), c1, "intermediate stays correct");
    assert_eq!(s.download(he).unwrap(), want, "chained launch uses the updated C");

    // B-panel packing amortizes: the b/d grids were each packed once, and
    // re-enqueueing over the same B reuses the cached grid
    let before = dev.metrics();
    s.enqueue_gemm(ha, hb, hc).unwrap();
    s.wait().unwrap();
    let after = dev.metrics();
    assert_eq!(after.panel_builds, before.panel_builds, "warm B grid must not repack");
    assert_eq!(after.panel_reuses, before.panel_reuses + 1);
    assert_eq!(s.download(hc).unwrap(), baseline::gemm_serial(&a, &b, &c1));
}

#[test]
fn independent_launches_pipeline_and_stay_bit_exact() {
    // The hazard-tracking acceptance criterion: launches with disjoint
    // buffer sets must be in flight simultaneously (no drain between
    // enqueues), and both results still match the serial baseline.
    let Some(dev) = device_env_tiles(2, 512) else { return };
    let a1 = Matrix::random(14, 10, 448, 500, 30);
    let b1 = Matrix::random(10, 12, 448, 501, 30);
    let c1 = Matrix::random(14, 12, 448, 502, 30);
    let a2 = Matrix::random(9, 11, 448, 503, 30);
    let b2 = Matrix::random(11, 13, 448, 504, 30);
    let c2 = Matrix::random(9, 13, 448, 505, 30);

    let mut s = dev.stream().unwrap();
    let (ha1, hb1, hc1) = (s.upload(&a1), s.upload(&b1), s.upload(&c1));
    let (ha2, hb2, hc2) = (s.upload(&a2), s.upload(&b2), s.upload(&c2));
    s.enqueue_gemm(ha1, hb1, hc1).unwrap();
    s.enqueue_gemm(ha2, hb2, hc2).unwrap();
    assert!(
        dev.metrics().inflight_max >= 2,
        "disjoint launches must overlap, got inflight_max {}",
        dev.metrics().inflight_max
    );
    s.wait().unwrap();
    assert_eq!(s.download(hc1).unwrap(), baseline::gemm_serial(&a1, &b1, &c1));
    assert_eq!(s.download(hc2).unwrap(), baseline::gemm_serial(&a2, &b2, &c2));
    let snap = dev.metrics();
    assert_eq!(snap.launches, 2, "both launches retired");
    assert!(snap.drain_ns > 0, "per-launch drain time must be recorded");
}

#[test]
fn dependent_chain_serializes_through_the_hazard_check() {
    // enqueue_gemm(c, b, c) reads what the previous launch wrote: the
    // hazard scan must drain between them (inflight_max stays 1) and the
    // chain must stay bit-identical to serial application.
    let Some(dev) = device_env_tiles(2, 512) else { return };
    let b = Matrix::random(12, 12, 448, 510, 25);
    let c = Matrix::random(12, 12, 448, 511, 25);
    let mut s = dev.stream().unwrap();
    let (hb, hc) = (s.upload(&b), s.upload(&c));
    let mut want = c.clone();
    for _ in 0..3 {
        s.enqueue_gemm(hc, hb, hc).unwrap();
        want = baseline::gemm_serial(&want, &b, &want);
    }
    assert_eq!(
        dev.metrics().inflight_max,
        1,
        "a dependent chain must never have two launches in flight"
    );
    assert_eq!(s.download(hc).unwrap(), want);
}

#[test]
fn download_drains_only_what_the_read_depends_on() {
    // Retirement is FIFO, so downloading a buffer lands every launch up to
    // its last writer — but launches writing other buffers stay in flight.
    let Some(dev) = device_env_tiles(2, 512) else { return };
    let a = Matrix::random(10, 8, 448, 520, 25);
    let b = Matrix::random(8, 9, 448, 521, 25);
    let c1 = Matrix::random(10, 9, 448, 522, 25);
    let c2 = Matrix::random(10, 9, 448, 523, 25);
    let mut s = dev.stream().unwrap();
    let (ha, hb) = (s.upload(&a), s.upload(&b));
    let (hc1, hc2) = (s.upload(&c1), s.upload(&c2));
    s.enqueue_gemm(ha, hb, hc1).unwrap();
    s.enqueue_gemm(ha, hb, hc2).unwrap();
    // downloading c1 retires launch 1 only; launch 2 still drains later
    assert_eq!(s.download(hc1).unwrap(), baseline::gemm_serial(&a, &b, &c1));
    assert_eq!(dev.metrics().launches, 1, "download must retire only up to c1's writer");
    assert_eq!(s.download(hc2).unwrap(), baseline::gemm_serial(&a, &b, &c2));
    assert_eq!(dev.metrics().launches, 2);
    // an untouched buffer downloads without draining anything
    s.enqueue_gemm(ha, hb, hc1).unwrap();
    assert_eq!(s.download(hb).unwrap(), b);
    s.wait().unwrap();
}

#[test]
fn stream_accumulates_in_place_when_output_aliases_input() {
    // enqueue_gemm(c, b, c): inputs are the pre-launch buffer contents, so
    // C += C_old @ B is well defined and matches the baseline on a copy.
    let Some(dev) = device(2, 512) else { return };
    let b = Matrix::random(9, 9, 448, 410, 20);
    let c = Matrix::random(9, 9, 448, 411, 20);
    let mut s = dev.stream().unwrap();
    let (hb, hc) = (s.upload(&b), s.upload(&c));
    s.enqueue_gemm(hc, hb, hc).unwrap();
    let want = baseline::gemm_serial(&c, &b, &c);
    assert_eq!(s.download(hc).unwrap(), want);
}

#[test]
fn stream_alloc_starts_zeroed_and_download_drains() {
    let Some(dev) = device(1, 512) else { return };
    let a = Matrix::random(6, 7, 448, 420, 20);
    let b = Matrix::random(7, 5, 448, 421, 20);
    let mut s = dev.stream().unwrap();
    let (ha, hb) = (s.upload(&a), s.upload(&b));
    let hc = s.alloc(6, 5);
    assert_eq!(s.download(hc).unwrap(), Matrix::zeros(6, 5, 448));
    s.enqueue_gemm(ha, hb, hc).unwrap();
    // download without an explicit wait() must drain the launch first
    let want = baseline::gemm_serial(&a, &b, &Matrix::zeros(6, 5, 448));
    assert_eq!(s.download(hc).unwrap(), want);
}
