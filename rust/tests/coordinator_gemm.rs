//! End-to-end coordinator test: multi-CU GEMM through the pluggable
//! backend, bit-compared against the software baseline (the paper's
//! verification methodology: accelerator output vs MPFR software
//! computation).
//!
//! On the default native backend the full device stack — scheduler
//! partition, bounded worker queues, tile K-accumulation, metrics — runs
//! on every checkout, with no `artifacts/` directory.  `APFP_BACKEND=xla`
//! drives the same tests through PJRT artifacts instead (skipping when
//! that runtime cannot come up).

use apfp::baseline;
use apfp::config::ApfpConfig;
use apfp::coordinator::{Device, Matrix};
use apfp::runtime::BackendKind;

fn device(cus: usize, bits: u32) -> Option<Device> {
    let dir = apfp::runtime::default_artifact_dir();
    let mut cfg = ApfpConfig { compute_units: cus, bits, ..Default::default() };
    cfg.tile_n = 16;
    cfg.tile_m = 16;
    let native = cfg.backend == BackendKind::Native;
    match Device::new(cfg, &dir) {
        Ok(dev) => Some(dev),
        // the xla backend legitimately skips without artifacts; the native
        // backend must come up on every checkout — a failure there is a
        // real regression, never a skip
        Err(e) if !native => {
            eprintln!("skipped: {e:#}");
            None
        }
        Err(e) => panic!("native device must open on a clean checkout: {e:#}"),
    }
}

#[test]
fn gemm_single_cu_bit_exact() {
    let Some(dev) = device(1, 512) else { return };
    let a = Matrix::random(24, 20, 448, 10, 40);
    let b = Matrix::random(20, 28, 448, 11, 40);
    let c = Matrix::random(24, 28, 448, 12, 40);
    let (got, stats) = dev.gemm(&a, &b, &c).unwrap();
    let want = baseline::gemm_serial(&a, &b, &c);
    assert_eq!(got, want, "device GEMM must be bit-identical to softfloat");
    assert!(stats.tiles > 0 && stats.artifact_calls >= stats.tiles);
}

#[test]
fn gemm_multi_cu_bit_exact_and_partitioned() {
    let Some(dev) = device(3, 512) else { return };
    // deliberately awkward sizes: not multiples of the tile or CU count,
    // so band ends fall mid-tile (the clipped-tile write path)
    let a = Matrix::random(37, 19, 448, 20, 40);
    let b = Matrix::random(19, 23, 448, 21, 40);
    let c = Matrix::random(37, 23, 448, 22, 40);
    let (got, stats) = dev.gemm(&a, &b, &c).unwrap();
    let want = baseline::gemm_serial(&a, &b, &c);
    assert_eq!(got, want);
    assert_eq!(dev.placements().len(), 3);
    assert!(stats.macs > 0);
}

#[test]
fn gemm_repeated_calls_accumulate_and_reuse_the_backend() {
    let Some(dev) = device(2, 512) else { return };
    let a = Matrix::random(16, 16, 448, 30, 20);
    let b = Matrix::random(16, 16, 448, 31, 20);
    let c0 = Matrix::zeros(16, 16, 448);
    let t0 = std::time::Instant::now();
    let (c1, _) = dev.gemm(&a, &b, &c0).unwrap();
    let first = t0.elapsed();
    let t1 = std::time::Instant::now();
    let (c2, _) = dev.gemm(&a, &b, &c1).unwrap();
    let second = t1.elapsed();
    // C accumulates (beta = 1): second call adds A*B again
    let want = baseline::gemm_serial(&a, &b, &c1);
    assert_eq!(c2, want);
    // On the xla path the compile happens once, so the second call must be
    // much faster.  (Native has nothing to compile; both calls are warm
    // and the timing comparison would be noise.)
    if dev.config().backend == BackendKind::Xla {
        assert!(second < first, "no executable reuse: {first:?} -> {second:?}");
    }
}

#[test]
fn stream_ops_through_device() {
    let Some(dev) = device(2, 512) else { return };
    let a = Matrix::random(1, 90, 448, 40, 100);
    let b = Matrix::random(1, 90, 448, 41, 100);
    let c = Matrix::random(1, 90, 448, 42, 100);
    let got = dev.mul_stream(a.values(), b.values()).unwrap();
    for (i, g) in got.iter().enumerate() {
        assert_eq!(*g, a.values()[i].mul(&b.values()[i]), "mul lane {i}");
    }
    let got = dev.add_stream(a.values(), b.values()).unwrap();
    for (i, g) in got.iter().enumerate() {
        assert_eq!(*g, a.values()[i].add(&b.values()[i]), "add lane {i}");
    }
    let got = dev.mac_stream(c.values(), a.values(), b.values()).unwrap();
    for (i, g) in got.iter().enumerate() {
        assert_eq!(*g, c.values()[i].mac(&a.values()[i], &b.values()[i]), "mac lane {i}");
    }
}

#[test]
fn gemm_1024_bits() {
    let Some(dev) = device(2, 1024) else { return };
    let a = Matrix::random(10, 9, 960, 50, 40);
    let b = Matrix::random(9, 12, 960, 51, 40);
    let c = Matrix::random(10, 12, 960, 52, 40);
    let (got, _) = dev.gemm(&a, &b, &c).unwrap();
    assert_eq!(got, baseline::gemm_serial(&a, &b, &c));
}

#[test]
fn native_device_runs_end_to_end_without_artifacts() {
    // The tentpole acceptance criterion: on a clean checkout with no
    // artifacts/ and no xla crate, the native backend lights up the whole
    // device stack and stays bit-identical to the softfloat baseline.
    let dir = std::env::temp_dir().join("apfp_native_no_artifacts/none");
    let cfg = ApfpConfig {
        backend: BackendKind::Native,
        compute_units: 2,
        ..Default::default()
    };
    let dev = Device::new(cfg, &dir).unwrap();
    let a = Matrix::random(13, 11, 448, 60, 40);
    let b = Matrix::random(11, 17, 448, 61, 40);
    let c = Matrix::random(13, 17, 448, 62, 40);
    let (got, stats) = dev.gemm(&a, &b, &c).unwrap();
    assert_eq!(got, baseline::gemm_serial(&a, &b, &c));
    assert!(stats.tiles > 0 && stats.artifact_calls >= stats.tiles && stats.macs > 0);
    let got = dev.mul_stream(a.row(0), a.row(1)).unwrap();
    for (i, g) in got.iter().enumerate() {
        assert_eq!(*g, a.row(0)[i].mul(&a.row(1)[i]), "mul lane {i}");
    }
}

#[test]
fn device_new_without_manifest_errors_cleanly_on_xla() {
    // The artifact-missing path must stay a clean Err on the xla backend
    // (it cannot run without HLO files), never a panic — and never a
    // silently fabricated manifest.
    let dir = std::env::temp_dir().join("apfp_no_artifacts_here");
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir).unwrap();
    let cfg = ApfpConfig { backend: BackendKind::Xla, ..Default::default() };
    let err = match Device::new(cfg.clone(), &dir) {
        Err(e) => e,
        Ok(_) => panic!("Device::new must fail without a manifest on xla"),
    };
    let msg = format!("{err:#}");
    assert!(msg.contains("manifest"), "error should name the missing manifest: {msg}");

    // a directory that does not exist at all behaves the same way
    let missing = dir.join("definitely/not/created");
    assert!(Device::new(cfg, &missing).is_err());
}

#[test]
fn device_new_rejects_invalid_config_before_touching_artifacts() {
    let bad = ApfpConfig { compute_units: 0, ..Default::default() };
    let dir = std::env::temp_dir().join("apfp_cfg_gate_unused");
    let err = match Device::new(bad, &dir) {
        Err(e) => format!("{e:#}"),
        Ok(_) => panic!("zero compute units must be rejected"),
    };
    // the config gate, not the (also-missing) manifest, must trip first
    assert!(err.contains("compute_units"), "unexpected error: {err}");
    assert!(!err.contains("manifest"), "config must be validated first: {err}");
}

#[test]
fn shape_mismatch_is_error() {
    let Some(dev) = device(1, 512) else { return };
    let a = Matrix::random(4, 5, 448, 60, 10);
    let b = Matrix::random(6, 4, 448, 61, 10); // 5 != 6
    let c = Matrix::zeros(4, 4, 448);
    assert!(dev.gemm(&a, &b, &c).is_err());
}
