//! Randomized parity: the const-generic fixed-width fast path must be
//! bit-identical to the dynamic `Scratch`-arena reference at the paper's
//! hot widths (448 bits = 7 limbs, 960 bits = 15 limbs) — including the
//! awkward operands: zeros, deeply negative exponents, and carry-chain
//! boundary mantissas (all-ones ripples the full adder; MSB-only sits one
//! ulp above the normalization floor).
//!
//! The Python port (python/tests/test_fixed_parity.py) replays the same
//! xorshift64* operand streams against an exact-integer RNDZ reference,
//! so the two suites pin the same behaviour from independent directions.

use apfp::baseline::{gemm_fixed, gemm_serial, pack_b_fixed};
use apfp::coordinator::Matrix;
use apfp::pack::PlaneBatch;
use apfp::runtime::{manifest, ArtifactKind, Backend, NativeBackend, TileShape};
use apfp::softfloat::{ApFloat, ApFloatN};
use apfp::testkit::{rand_ap, Rng};

/// Operand mix: mostly random normalized values, salted with zeros,
/// carry-chain boundary mantissas, and deeply negative exponents.
fn operand<const L: usize>(rng: &mut Rng, prec: u32) -> ApFloatN<L> {
    match rng.below(16) {
        0 => ApFloatN::ZERO,
        1 | 2 => {
            let mant = if rng.bool() {
                [u64::MAX; L]
            } else {
                let mut m = [0u64; L];
                m[L - 1] = 1 << 63;
                m
            };
            ApFloatN::from_parts(rng.bool(), rng.range_i64(-300, 300), mant)
        }
        3 | 4 => {
            let v = rand_ap(rng, prec, 4);
            let f = ApFloatN::<L>::from_ap(&v);
            if f.is_zero() {
                f
            } else {
                ApFloatN::from_parts(f.sign(), rng.range_i64(-2000, -500), *f.limbs())
            }
        }
        _ => ApFloatN::from_ap(&rand_ap(rng, prec, 300)),
    }
}

/// mul/add/sub/mac on independent operands, fixed vs dynamic, bitwise.
fn scalar_parity<const L: usize>(prec: u32, seed: u64, cases: u64) {
    let mut rng = Rng::from_seed(seed);
    for case in 0..cases {
        let af = operand::<L>(&mut rng, prec);
        let bf = operand::<L>(&mut rng, prec);
        let accf = operand::<L>(&mut rng, prec);
        let ad = af.to_ap();
        let bd = bf.to_ap();
        let accd = accf.to_ap();
        assert_eq!(af.mul(&bf).to_ap(), ad.mul(&bd), "mul case {case} at prec {prec}");
        assert_eq!(af.add(&bf).to_ap(), ad.add(&bd), "add case {case} at prec {prec}");
        assert_eq!(af.sub(&bf).to_ap(), ad.sub(&bd), "sub case {case} at prec {prec}");
        assert_eq!(
            accf.mac(&af, &bf).to_ap(),
            accd.mac(&ad, &bd),
            "mac case {case} at prec {prec}"
        );
    }
}

#[test]
fn scalar_ops_bit_identical_448() {
    scalar_parity::<7>(448, 0xF1A8_0448, 2000);
}

#[test]
fn scalar_ops_bit_identical_960() {
    scalar_parity::<15>(960, 0xF1A8_0960, 2000);
}

/// A long in-place MAC chain — the GEMM inner loop's exact usage — must
/// track the dynamic accumulator bit for bit at every step, so rounding
/// differences cannot hide behind later accumulation.
fn mac_chain_parity<const L: usize>(prec: u32, seed: u64) {
    let mut rng = Rng::from_seed(seed);
    let mut accf = ApFloatN::<L>::ZERO;
    let mut accd = ApFloat::zero(prec);
    for step in 0..512 {
        let af = operand::<L>(&mut rng, prec);
        let bf = operand::<L>(&mut rng, prec);
        accf.mac_into(&af, &bf);
        accd = accd.mac(&af.to_ap(), &bf.to_ap());
        assert_eq!(accf.to_ap(), accd, "mac chain step {step} at prec {prec}");
    }
}

#[test]
fn mac_chain_bit_identical_448() {
    mac_chain_parity::<7>(448, 0xC4A1_0448);
}

#[test]
fn mac_chain_bit_identical_960() {
    mac_chain_parity::<15>(960, 0xC4A1_0960);
}

/// Whole-tile parity: `gemm_fixed` vs `gemm_serial` on random matrices
/// with a zero element salted in, accumulated twice so C enters the
/// second round non-trivial.
fn gemm_parity<const L: usize>(prec: u32, seed: u64) {
    let (n, k, m) = (5usize, 7, 6);
    let mut a = Matrix::random(n, k, prec, seed, 60);
    a.set(0, 3, ApFloat::zero(prec));
    let b = Matrix::random(k, m, prec, seed + 1, 60);
    let c = Matrix::random(n, m, prec, seed + 2, 60);

    let mut af: Vec<ApFloatN<L>> = Vec::new();
    for i in 0..n {
        for kk in 0..k {
            af.push(ApFloatN::from_ap(a.get(i, kk)));
        }
    }
    let mut bt = Vec::new();
    pack_b_fixed::<L>(&b, &mut bt);
    let mut cf: Vec<ApFloatN<L>> = Vec::new();
    for i in 0..n {
        for j in 0..m {
            cf.push(ApFloatN::from_ap(c.get(i, j)));
        }
    }

    let mut want = c.clone();
    for round in 0..2 {
        gemm_fixed(&af, &bt, &mut cf, n, k, m);
        want = gemm_serial(&a, &b, &want);
        for i in 0..n {
            for j in 0..m {
                assert_eq!(
                    &cf[i * m + j].to_ap(),
                    want.get(i, j),
                    "gemm round {round} element ({i},{j}) at prec {prec}"
                );
            }
        }
    }
}

#[test]
fn gemm_fixed_bit_identical_448() {
    gemm_parity::<7>(448, 0x6E11_0448);
}

#[test]
fn gemm_fixed_bit_identical_960() {
    gemm_parity::<15>(960, 0x6E11_0960);
}

/// End-to-end lane parity: the native backend with the fixed lane enabled
/// must produce byte-identical output planes to the dynamic lane on the
/// same tile, at both hot device widths.
#[test]
fn native_lanes_bit_identical() {
    for bits in [512u32, 1024] {
        let meta = manifest::builtin(bits, TileShape { n: 6, m: 5, k: 4 })
            .unwrap()
            .into_iter()
            .find(|m| m.kind == ArtifactKind::Gemm)
            .expect("builtin gemm artifact");
        let prec = meta.prec();
        let (tn, tm, kt) = (meta.t_n, meta.t_m, meta.k_tile);
        let mut rng = Rng::from_seed(0x1A6E ^ u64::from(bits));
        let batch = |count: usize, rng: &mut Rng| -> PlaneBatch {
            let mut vals: Vec<ApFloat> = (0..count).map(|_| rand_ap(rng, prec, 30)).collect();
            vals[count / 2] = ApFloat::zero(prec); // a zero lane must round-trip
            PlaneBatch::from_slice(&vals, prec)
        };
        let a = batch(tn * kt, &mut rng);
        let b = batch(kt * tm, &mut rng);
        let c0 = batch(tn * tm, &mut rng);

        let fixed = NativeBackend::with_fixed_path(true);
        let dynamic = NativeBackend::with_fixed_path(false);
        let mut c_fixed = c0.clone();
        let mut c_dyn = c0.clone();
        for round in 0..3 {
            fixed.exec_gemm_tile(&meta, &a, &b, &mut c_fixed).unwrap();
            dynamic.exec_gemm_tile(&meta, &a, &b, &mut c_dyn).unwrap();
            assert_eq!(
                c_fixed, c_dyn,
                "fixed and dynamic lanes diverged on round {round} at {bits} bits"
            );
        }
    }
}
