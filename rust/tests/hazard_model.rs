//! Exhaustive-interleaving model of the stream's launch-hazard protocol.
//!
//! `src/coordinator/stream.rs` pipelines independent launches and defers
//! every writeback to FIFO retirement; the safety argument (see the
//! module docs there and ARCHITECTURE.md §"Launch hazards") is:
//!
//! 1. an enqueue drains every in-flight launch that *writes* one of its
//!    three buffers (RAW/WAW), so after the enqueue no in-flight writer
//!    of its read set exists;
//! 2. writebacks land only at retirement and retirement is strictly in
//!    enqueue order, so a later writer can never overtake an earlier
//!    reader (WAR needs no wait at all);
//! 3. staging buffers ride the reply on **every** arm — success, failed
//!    tile, caught panic — so the pool is conserved unless a worker dies
//!    reply-less, in which case the stream is poisoned rather than left
//!    with unprovable buffer ownership;
//! 4. (ISSUE 7) a failed reply inside the retry budget is *redispatched*
//!    with the buffer it came home in — the retry arm neither leaks nor
//!    mints staging buffers, retries happen at the owning launch's own
//!    retirement (FIFO order is untouched), and a reply-less death that
//!    bottoms out the supervision ladder (respawn budget spent, every CU
//!    quarantined) surfaces as `NoSurvivors` before poisoning.
//!
//! Those claims are about *interleavings*, which the integration tests
//! sample but cannot enumerate.  This file re-states the protocol as a
//! small explicit-state model (same structure, same names as stream.rs:
//! `enqueue`, `retire_one`, the hazard scan, the grid-rebuild conflict)
//! and drives it through **every** schedule of worker events with a
//! depth-first search — a zero-dependency stand-in for a `loom`-style
//! checker, which is unavailable offline.  The model is falsifiable: the
//! `eager_writeback` variant (writeback at last reply instead of at
//! retirement — exactly the bug rule 2 exists to prevent) is shown to
//! violate read stability in at least one schedule, so a protocol
//! regression re-introduced in the model would be caught, not vacuously
//! passed.
//!
//! The static side of the same contract is `cargo xtask lint`'s `hazard`
//! rule (docs/INVARIANTS.md): every `TileResult` carries `c_buf`, reply
//! receives are `recv_timeout`, reply channels are bounded.

// A model test asserts by panicking; the crate's panic discipline
// applies to the device stack, not to tests (see clippy.toml).
#![allow(clippy::unwrap_used, clippy::expect_used)]

use std::collections::VecDeque;

// ---------------------------------------------------------------------------
// Scenario vocabulary
// ---------------------------------------------------------------------------

/// What the (modeled) worker does with one tile job.
#[derive(Clone, Copy, PartialEq, Eq, Debug)]
enum Outcome {
    /// Computes the tile and replies with the staging buffer.
    Ok,
    /// Hits a backend error; replies with `err` set — and the buffer.
    Fail,
    /// Panics; the catch wrapper still replies with `err` — and the buffer.
    Panic,
    /// Transient: errors on the first `K` delivery attempts, then
    /// computes — the `fail_tile=RxC*K` failpoint under retry.
    Flaky(u32),
    /// Dies reply-less with the supervision ladder bottomed out (respawn
    /// budget spent, zero CUs survive): the buffer is lost and the reply
    /// never arrives.
    Dead,
}

/// Does this outcome reply with `err` set at delivery `attempt`?
fn failed_at(o: Outcome, attempt: u32) -> bool {
    match o {
        Outcome::Fail | Outcome::Panic => true,
        Outcome::Flaky(k) => attempt < k,
        Outcome::Ok | Outcome::Dead => false,
    }
}

/// Leader-side API calls, in program order.
#[derive(Clone, Copy, PartialEq, Eq, Debug)]
enum Op {
    /// `enqueue_gemm(a, b, c)`: read set `{a, b, c}`, write set `{c}`.
    Enqueue(usize, usize, usize),
    /// `wait()`: retire everything in flight.
    Wait,
    /// `download(x)`: retire through the last in-flight writer of `x`.
    Download(usize),
}

struct Scenario {
    /// Number of device buffers; ids are indices.
    bufs: usize,
    /// Tiles per launch (every launch gets the same count).
    tiles_per_launch: usize,
    ops: Vec<Op>,
    /// `outcomes[launch_id][tile]`; entries missing here default to `Ok`.
    outcomes: Vec<Vec<Outcome>>,
    /// `RetryPolicy::retry_limit`: redispatches granted to a failed tile
    /// (so each tile is delivered at most `retry_limit + 1` times).
    retry_limit: u32,
    /// Protocol mutation: write C back when the *last reply* arrives
    /// instead of at FIFO retirement.  Used to prove the model can fail.
    eager_writeback: bool,
}

impl Scenario {
    fn outcome(&self, launch: usize, tile: usize) -> Outcome {
        self.outcomes.get(launch).and_then(|l| l.get(tile)).copied().unwrap_or(Outcome::Ok)
    }
}

// ---------------------------------------------------------------------------
// Model state (mirrors DeviceStream's leader state)
// ---------------------------------------------------------------------------

#[derive(Clone, Copy, PartialEq, Eq, Debug)]
enum TileSt {
    /// Submitted to a worker queue, not yet picked up.
    Queued,
    /// Executed; the reply (with the staging buffer) sits in the channel.
    Replied,
    /// Executed by a dying worker; no reply will ever arrive.
    Lost,
}

#[derive(Clone, Debug)]
struct Tile {
    st: TileSt,
    outcome: Outcome,
    /// 0-based delivery count, echoed through the reply — the retry arm's
    /// bookkeeping (stream.rs stamps the same counter on `Job::GemmTile`).
    attempt: u32,
    /// Buffer contents the worker saw at execution time (`None` = queued).
    observed: Option<[u32; 3]>,
}

#[derive(Clone, Debug)]
struct Launch {
    id: usize,
    a: usize,
    b: usize,
    c: usize,
    /// Read-set contents at enqueue: what every tile of this launch must
    /// observe, per the stability argument in the module docs.
    snapshot: [u32; 3],
    tiles: Vec<Tile>,
}

impl Launch {
    fn references(&self, buf: usize) -> bool {
        self.a == buf || self.b == buf || self.c == buf
    }
}

/// One explored copy of the world.  `Clone` at every branch point is the
/// whole trick: the DFS owns its states, no real threads are involved.
#[derive(Clone)]
struct Model {
    /// Committed contents of each device buffer, as a write counter.
    buf_val: Vec<u32>,
    /// B-tile grid cache: the `buf_val` the grid was cut from, per buffer.
    grid: Vec<Option<u32>>,
    inflight: VecDeque<Launch>,
    next_launch: usize,
    /// Program counter into `Scenario::ops`.
    pc: usize,
    /// Staging buffers currently held by jobs or un-drained replies.
    staging_out: usize,
    /// Staging buffers that died with their worker (Dead outcomes run).
    staging_lost: usize,
    poisoned: bool,
    /// The op index `check_live` last ran for — the real stream checks
    /// poison once per API call, not once per internal drain step.
    live_checked_pc: Option<usize>,
    /// Typed-error stand-ins the leader observed, in order.
    errors: Vec<String>,
    inflight_max: usize,
    /// Hazard drains forced by an `Enqueue` (not by `Wait`/`Download`).
    hazard_drains: usize,
    /// Failed replies redispatched within the retry budget.
    retries: usize,
}

#[derive(Default)]
struct Stats {
    /// Distinct complete schedules explored.
    schedules: usize,
    /// Protocol violations found (empty = the invariants hold everywhere).
    violations: Vec<String>,
    inflight_max: usize,
    hazard_drains_min: usize,
    hazard_drains_max: usize,
    /// Retry redispatches, min/max across schedules: equal bounds prove
    /// the retry count is schedule-independent (leader-deterministic).
    retries_min: usize,
    retries_max: usize,
    /// Staging buffers unaccounted for at quiescence, worst schedule.
    leaked_max: usize,
    errors_seen: Vec<String>,
}

enum Step {
    Ran,
    /// The next retirement needs replies only workers can produce.
    Blocked,
    Done,
}

impl Model {
    fn new(sc: &Scenario) -> Self {
        Model {
            buf_val: vec![0; sc.bufs],
            grid: vec![None; sc.bufs],
            inflight: VecDeque::new(),
            next_launch: 0,
            pc: 0,
            staging_out: 0,
            staging_lost: 0,
            poisoned: false,
            live_checked_pc: None,
            errors: Vec::new(),
            inflight_max: 0,
            hazard_drains: 0,
            retries: 0,
        }
    }

    /// The retry arm, applied where the real drain loop applies it: while
    /// retiring the *front* launch (FIFO — a retry never escapes its own
    /// launch's retirement).  A failed reply with budget left goes back
    /// to `Queued` at `attempt + 1`, reusing the staging buffer it came
    /// home in — `staging_out` is untouched, which is exactly the
    /// conservation claim of invariant 4.
    fn maybe_retry_front(&mut self, sc: &Scenario) {
        let Some(l) = self.inflight.front_mut() else { return };
        for t in &mut l.tiles {
            if t.st == TileSt::Replied
                && failed_at(t.outcome, t.attempt)
                && t.attempt < sc.retry_limit
            {
                t.st = TileSt::Queued;
                t.attempt += 1;
                t.observed = None;
                self.retries += 1;
            }
        }
    }

    /// Can the oldest in-flight launch retire without further worker
    /// progress?  Mirrors `retire_one`'s drain loop: it completes once
    /// every reply arrived or the lost ones were declared dead.
    fn front_drainable(&self) -> bool {
        self.inflight.front().map_or(false, |l| l.tiles.iter().all(|t| t.st != TileSt::Queued))
    }

    /// `retire_one`: drain the oldest launch's replies, recover staging
    /// buffers per arm, write back only on the all-healthy arm.
    /// Caller must have checked `front_drainable`.
    fn retire_one(&mut self, sc: &Scenario, out: &mut Stats) -> Result<(), String> {
        let l = self.inflight.pop_front().expect("retire_one on an empty pipeline");
        let lost = l.tiles.iter().filter(|t| t.st == TileSt::Lost).count();
        let replied = l.tiles.len() - lost;
        // Every reply that did arrive returns its staging buffer, on every
        // arm — the `c_buf`-on-every-arm invariant the lint checks.
        self.staging_out -= replied;
        if lost > 0 {
            // The ladder's bottom: a reply-less death with no survivor to
            // replay onto.  Recover what arrived, write nothing, poison.
            self.poisoned = true;
            return Err(format!("NoSurvivors(launch {}, missing {lost})", l.id));
        }
        // A tile still failing at its settled attempt exhausted its retry
        // budget (maybe_retry_front requeued everything under budget).
        let failed = l.tiles.iter().filter(|t| failed_at(t.outcome, t.attempt)).count();
        if failed > 0 {
            // LaunchFailed: fully drained, C untouched, stream stays usable.
            return Err(format!("LaunchFailed(launch {}, {failed} tiles)", l.id));
        }
        // Healthy arm: read stability is the theorem under test — every
        // tile must have observed exactly the pre-launch contents.
        for (i, t) in l.tiles.iter().enumerate() {
            let obs = t.observed.expect("drainable launch has an unexecuted tile");
            if obs != l.snapshot {
                out.violations.push(format!(
                    "launch {} tile {i} read {:?}, enqueue snapshot was {:?}",
                    l.id, obs, l.snapshot
                ));
            }
        }
        if !sc.eager_writeback {
            // Writeback at retirement bumps the version, which is what
            // invalidates B grids cut from the old contents.
            self.buf_val[l.c] += 1;
        }
        Ok(())
    }

    /// `check_live`, once per API call: a poisoned stream reports instead
    /// of hanging.  Returns true when the current op must be skipped.
    fn op_rejected_by_poison(&mut self) -> bool {
        if self.live_checked_pc == Some(self.pc) {
            return false; // mid-op re-entry: drains continue even poisoned
        }
        self.live_checked_pc = Some(self.pc);
        if self.poisoned {
            self.errors.push("Poisoned".to_string());
            return true;
        }
        false
    }

    /// Run one leader op (or one internal drain step of it) if it can
    /// make progress without worker help.
    fn leader_step(&mut self, sc: &Scenario, out: &mut Stats) -> Step {
        let Some(op) = sc.ops.get(self.pc).copied() else {
            return Step::Done;
        };
        if self.op_rejected_by_poison() {
            self.pc += 1;
            return Step::Ran;
        }
        match op {
            Op::Enqueue(a, b, c) => {
                // Hazard scan, verbatim from stream.rs: conflict = an
                // in-flight launch writing one of {a, b, c}, or — when
                // b's grid must be (re)built — any launch referencing b.
                let grid_fresh = self.grid[b] == Some(self.buf_val[b]);
                let mut conflict = false;
                for l in &self.inflight {
                    let writes_our_set = l.c == a || l.c == b || l.c == c;
                    let blocks_grid_build = !grid_fresh && l.references(b);
                    if writes_our_set || blocks_grid_build {
                        conflict = true;
                    }
                }
                if conflict {
                    // Drain the front launch, then re-run the scan; the
                    // real code's retire_n(i + 1) is this loop unrolled.
                    self.maybe_retry_front(sc);
                    if !self.front_drainable() {
                        return Step::Blocked;
                    }
                    self.hazard_drains += 1;
                    if let Err(e) = self.retire_one(sc, out) {
                        // A drain error surfaces here and the launch is
                        // NOT submitted.
                        self.errors.push(e);
                        self.pc += 1;
                    }
                    return Step::Ran;
                }
                // Cut (or reuse) b's tile grid, then submit every tile.
                self.grid[b] = Some(self.buf_val[b]);
                let id = self.next_launch;
                self.next_launch += 1;
                let tiles = (0..sc.tiles_per_launch)
                    .map(|t| Tile {
                        st: TileSt::Queued,
                        outcome: sc.outcome(id, t),
                        attempt: 0,
                        observed: None,
                    })
                    .collect();
                self.staging_out += sc.tiles_per_launch;
                self.inflight.push_back(Launch {
                    id,
                    a,
                    b,
                    c,
                    snapshot: [self.buf_val[a], self.buf_val[b], self.buf_val[c]],
                    tiles,
                });
                self.inflight_max = self.inflight_max.max(self.inflight.len());
                self.pc += 1;
                Step::Ran
            }
            Op::Wait => {
                if self.inflight.is_empty() {
                    self.pc += 1;
                    return Step::Ran;
                }
                self.maybe_retry_front(sc);
                if !self.front_drainable() {
                    return Step::Blocked;
                }
                // Later launches drain even when earlier ones error —
                // retire_n aggregates; the model records each error.
                if let Err(e) = self.retire_one(sc, out) {
                    self.errors.push(e);
                }
                Step::Ran // pc advances once the pipeline is empty
            }
            Op::Download(x) => {
                if self.inflight.iter().rposition(|l| l.c == x).is_none() {
                    self.pc += 1;
                    return Step::Ran;
                }
                self.maybe_retry_front(sc);
                if !self.front_drainable() {
                    return Step::Blocked;
                }
                if let Err(e) = self.retire_one(sc, out) {
                    self.errors.push(e);
                }
                Step::Ran // keep retiring until the last writer landed
            }
        }
    }

    /// Every worker event the scheduler could fire next: any queued tile
    /// of any in-flight launch (cross-CU and cross-launch reordering).
    fn enabled_worker_steps(&self) -> Vec<(usize, usize)> {
        let mut v = Vec::new();
        for (li, l) in self.inflight.iter().enumerate() {
            for (ti, t) in l.tiles.iter().enumerate() {
                if t.st == TileSt::Queued {
                    v.push((li, ti));
                }
            }
        }
        v
    }

    /// A worker picks up tile `ti` of in-flight launch `li`.
    fn worker_step(&mut self, li: usize, ti: usize, sc: &Scenario, out: &mut Stats) {
        let l = &self.inflight[li];
        let observed = [self.buf_val[l.a], self.buf_val[l.b], self.buf_val[l.c]];
        // Grid exclusivity: the grid a worker reads must be the one cut
        // for this launch — a rebuild mid-flight would be a lost update.
        if self.grid[l.b] != Some(l.snapshot[1]) {
            out.violations.push(format!("launch {} executed against a rebuilt B grid", l.id));
        }
        let c = l.c;
        let l = &mut self.inflight[li];
        let outcome = l.tiles[ti].outcome;
        l.tiles[ti].observed = Some(observed);
        if outcome == Outcome::Dead {
            // The buffer rides into the grave with the worker; quiescence
            // accounting expects exactly this many unreturned buffers.
            l.tiles[ti].st = TileSt::Lost;
            self.staging_lost += 1;
            return;
        }
        l.tiles[ti].st = TileSt::Replied;
        if sc.eager_writeback
            && outcome == Outcome::Ok
            && self.inflight[li].tiles.iter().all(|t| t.st == TileSt::Replied)
        {
            // The deliberate protocol bug: land the writeback as soon as
            // the last reply arrives, ignoring FIFO retirement order.
            self.buf_val[c] += 1;
        }
    }

    /// Terminal-state accounting, after the script ran to completion.
    fn check_quiescent(&self, out: &mut Stats) {
        // Every scenario ends with `Wait`, so a live stream ends empty; a
        // poisoned one may strand launches (the real stream refuses to
        // touch them — buffer ownership can no longer be proven).
        if !self.inflight.is_empty() && !self.poisoned {
            out.violations
                .push(format!("live stream ended with {} launches in flight", self.inflight.len()));
        }
        // Conservation: every staging buffer came home except the ones a
        // dying worker took with it (and those stranded by poison).
        let stranded: usize = self.inflight.iter().map(|l| l.tiles.len()).sum();
        let lost_in_flight = self
            .inflight
            .iter()
            .flat_map(|l| l.tiles.iter())
            .filter(|t| t.st == TileSt::Lost)
            .count();
        let expected = self.staging_lost + stranded - lost_in_flight;
        if self.staging_out != expected {
            out.violations.push(format!(
                "quiescent with {} staging buffers out ({expected} expected)",
                self.staging_out
            ));
        }
        out.leaked_max = out.leaked_max.max(self.staging_out);
        out.inflight_max = out.inflight_max.max(self.inflight_max);
        out.hazard_drains_min = out.hazard_drains_min.min(self.hazard_drains);
        out.hazard_drains_max = out.hazard_drains_max.max(self.hazard_drains);
        out.retries_min = out.retries_min.min(self.retries);
        out.retries_max = out.retries_max.max(self.retries);
        for e in &self.errors {
            if !out.errors_seen.contains(e) {
                out.errors_seen.push(e.clone());
            }
        }
    }
}

// ---------------------------------------------------------------------------
// The exhaustive scheduler
// ---------------------------------------------------------------------------

fn dfs(mut m: Model, sc: &Scenario, out: &mut Stats) {
    // The leader runs deterministically until it blocks on worker
    // replies; worker events only *read* leader-visible state, so
    // exploring their orders at block points covers every distinguishable
    // schedule (a partial-order reduction, not an approximation).
    loop {
        match m.leader_step(sc, out) {
            Step::Ran => continue,
            Step::Blocked => break,
            Step::Done => {
                out.schedules += 1;
                m.check_quiescent(out);
                return;
            }
        }
    }
    let choices = m.enabled_worker_steps();
    // Liveness: a blocked leader always has a runnable worker event —
    // the model analog of "recv_timeout + dead-worker probe never hangs".
    assert!(
        !choices.is_empty(),
        "deadlock: leader blocked at pc {} with no runnable worker event",
        m.pc
    );
    for (li, ti) in choices {
        let mut next = m.clone();
        next.worker_step(li, ti, sc, out);
        dfs(next, sc, out);
    }
}

fn explore(sc: &Scenario) -> Stats {
    let mut out =
        Stats { hazard_drains_min: usize::MAX, retries_min: usize::MAX, ..Stats::default() };
    dfs(Model::new(sc), sc, &mut out);
    assert!(out.schedules > 0, "the scenario never reached a terminal state");
    out
}

// ---------------------------------------------------------------------------
// Scenarios
// ---------------------------------------------------------------------------

/// Disjoint buffer sets pipeline: no hazard drain, two launches in
/// flight at once, pool conserved — under every schedule.
#[test]
fn disjoint_launches_pipeline_and_conserve_buffers() {
    let sc = Scenario {
        bufs: 6,
        tiles_per_launch: 2,
        ops: vec![Op::Enqueue(0, 1, 2), Op::Enqueue(3, 4, 5), Op::Wait],
        outcomes: vec![],
        retry_limit: 0,
        eager_writeback: false,
    };
    let st = explore(&sc);
    assert!(st.violations.is_empty(), "violations: {:?}", st.violations);
    assert!(st.schedules > 1, "the DFS must branch over schedules, got {}", st.schedules);
    assert_eq!(st.inflight_max, 2, "disjoint launches must overlap in flight");
    assert_eq!(st.hazard_drains_max, 0, "disjoint launches must not force a drain");
    assert_eq!(st.leaked_max, 0);
    assert!(st.errors_seen.is_empty(), "errors: {:?}", st.errors_seen);
}

/// `enqueue(c, b, c)` after `enqueue(a, b, c)`: RAW/WAW on C forces a
/// drain at the second enqueue, and the chained launch reads the
/// writer's retired value in every schedule.
#[test]
fn dependent_chain_reads_the_writers_retired_value() {
    let sc = Scenario {
        bufs: 3,
        tiles_per_launch: 2,
        ops: vec![Op::Enqueue(0, 1, 2), Op::Enqueue(2, 1, 2), Op::Wait],
        outcomes: vec![],
        retry_limit: 0,
        eager_writeback: false,
    };
    let st = explore(&sc);
    assert!(st.violations.is_empty(), "violations: {:?}", st.violations);
    assert!(st.hazard_drains_min >= 1, "the chain must drain its writer first");
    assert_eq!(st.inflight_max, 1, "a dependent chain cannot overlap");
    assert!(st.errors_seen.is_empty(), "errors: {:?}", st.errors_seen);
}

/// Write-after-read needs no wait: a later launch may write a buffer an
/// in-flight launch is reading, because its writeback is deferred to
/// FIFO retirement.  The reader's tiles must still observe pre-launch
/// contents in every schedule.
#[test]
fn write_after_read_defers_to_retirement() {
    // L0 reads buffer 2 (as A); L1 writes it.  A *reader* is not a
    // conflict for the scan, so both stay in flight.
    let sc = Scenario {
        bufs: 4,
        tiles_per_launch: 2,
        ops: vec![Op::Enqueue(2, 1, 3), Op::Enqueue(0, 1, 2), Op::Wait],
        outcomes: vec![],
        retry_limit: 0,
        eager_writeback: false,
    };
    let st = explore(&sc);
    assert!(st.violations.is_empty(), "violations: {:?}", st.violations);
    assert_eq!(st.inflight_max, 2, "WAR must not force a drain");
    assert_eq!(st.hazard_drains_max, 0);
}

/// The model is falsifiable: land L1's writeback eagerly (at last reply,
/// not at retirement) and some schedule must catch L0 reading torn
/// contents.  This is the exact bug the deferred-writeback rule
/// prevents; a model that could not detect it would prove nothing.
#[test]
fn eager_writeback_is_caught_as_a_stability_violation() {
    let sc = Scenario {
        bufs: 4,
        tiles_per_launch: 2,
        ops: vec![Op::Enqueue(2, 1, 3), Op::Enqueue(0, 1, 2), Op::Wait],
        outcomes: vec![],
        retry_limit: 0,
        eager_writeback: true,
    };
    let st = explore(&sc);
    assert!(
        !st.violations.is_empty(),
        "the eager-writeback mutation must violate read stability in some schedule"
    );
    assert!(
        st.violations.iter().any(|v| v.contains("snapshot")),
        "the violation must be a snapshot mismatch, got {:?}",
        st.violations
    );
}

/// Rebuilding B's tile grid needs exclusivity: an in-flight launch still
/// referencing the buffer (here: as its A operand) blocks the build, so
/// the enqueue drains it first.  No schedule may execute a tile against
/// a grid rebuilt after its enqueue.
#[test]
fn grid_rebuild_waits_for_inflight_referencers() {
    // L0 = (1, 0, 3) references buffer 1 as A; L1 = (2, 1, 4) uses it as
    // B with no grid yet cut -> blocks_grid_build forces a drain.
    let sc = Scenario {
        bufs: 5,
        tiles_per_launch: 2,
        ops: vec![Op::Enqueue(1, 0, 3), Op::Enqueue(2, 1, 4), Op::Wait],
        outcomes: vec![],
        retry_limit: 0,
        eager_writeback: false,
    };
    let st = explore(&sc);
    assert!(st.violations.is_empty(), "violations: {:?}", st.violations);
    assert!(st.hazard_drains_min >= 1, "the grid build must drain the referencing launch");
}

/// A failed tile: the launch drains completely, C keeps its pre-launch
/// contents, every staging buffer returns, and the stream stays usable
/// (the follow-up launch succeeds) — in every schedule.
#[test]
fn failed_tiles_write_nothing_and_return_every_buffer() {
    let sc = Scenario {
        bufs: 6,
        tiles_per_launch: 2,
        ops: vec![Op::Enqueue(0, 1, 2), Op::Wait, Op::Enqueue(3, 4, 5), Op::Wait],
        outcomes: vec![vec![Outcome::Ok, Outcome::Fail]],
        retry_limit: 0,
        eager_writeback: false,
    };
    let st = explore(&sc);
    assert!(st.violations.is_empty(), "violations: {:?}", st.violations);
    assert_eq!(st.leaked_max, 0, "failure arms must still return staging buffers");
    assert!(
        st.errors_seen.iter().any(|e| e.starts_with("LaunchFailed")),
        "errors: {:?}",
        st.errors_seen
    );
    assert!(
        !st.errors_seen.iter().any(|e| e == "Poisoned"),
        "a failed launch must not poison the stream: {:?}",
        st.errors_seen
    );
}

/// A caught worker panic rides the same failure arm as a backend error:
/// reply with `err` set, staging buffer recovered, stream usable.
#[test]
fn caught_panics_ride_the_failure_arm() {
    let sc = Scenario {
        bufs: 6,
        tiles_per_launch: 2,
        ops: vec![Op::Enqueue(0, 1, 2), Op::Wait, Op::Enqueue(3, 4, 5), Op::Wait],
        outcomes: vec![vec![Outcome::Panic, Outcome::Ok]],
        retry_limit: 0,
        eager_writeback: false,
    };
    let st = explore(&sc);
    assert!(st.violations.is_empty(), "violations: {:?}", st.violations);
    assert_eq!(st.leaked_max, 0);
    assert!(st.errors_seen.iter().any(|e| e.starts_with("LaunchFailed")));
    assert!(!st.errors_seen.iter().any(|e| e == "Poisoned"));
}

/// A worker death that bottoms out the supervision ladder (no survivor
/// to replay onto): the retirement reports NoSurvivors and poisons the
/// stream — every later call errors instead of hanging — and exactly the
/// dead worker's buffer is unaccounted for.
#[test]
fn zero_survivor_death_poisons_the_stream() {
    let sc = Scenario {
        bufs: 6,
        tiles_per_launch: 2,
        ops: vec![Op::Enqueue(0, 1, 2), Op::Wait, Op::Enqueue(3, 4, 5), Op::Wait],
        outcomes: vec![vec![Outcome::Ok, Outcome::Dead]],
        retry_limit: 0,
        eager_writeback: false,
    };
    let st = explore(&sc);
    assert!(st.violations.is_empty(), "violations: {:?}", st.violations);
    assert_eq!(st.leaked_max, 1, "exactly the dead worker's staging buffer is lost");
    assert!(st.errors_seen.iter().any(|e| e.starts_with("NoSurvivors")), "{:?}", st.errors_seen);
    assert!(
        st.errors_seen.iter().any(|e| e == "Poisoned"),
        "the call after a zero-survivor death must observe poison: {:?}",
        st.errors_seen
    );
}

/// The retry arm, invariant 4: a transient tile (fails twice, then
/// computes) inside the budget heals with **no** surfaced error, every
/// staging buffer conserved, FIFO retirement untouched — and the chained
/// follow-up launch reads the healed writeback (read stability would
/// flag a stale or torn value).  The retry count is the same in every
/// schedule: retries are leader-deterministic, not racy.
#[test]
fn flaky_tiles_retry_to_success_and_conserve_buffers() {
    let sc = Scenario {
        bufs: 4,
        tiles_per_launch: 2,
        // L1 chains on L0's output: its enqueue hazard-drains L0, so the
        // retries run inside that drain — the earliest the real stream
        // can run them too.
        ops: vec![Op::Enqueue(0, 1, 2), Op::Enqueue(2, 1, 3), Op::Wait],
        outcomes: vec![vec![Outcome::Flaky(2), Outcome::Ok]],
        retry_limit: 2,
        eager_writeback: false,
    };
    let st = explore(&sc);
    assert!(st.violations.is_empty(), "violations: {:?}", st.violations);
    assert!(st.errors_seen.is_empty(), "a healed launch surfaces nothing: {:?}", st.errors_seen);
    assert_eq!(st.leaked_max, 0, "the retry arm must reuse the returned buffer");
    assert_eq!(
        (st.retries_min, st.retries_max),
        (2, 2),
        "exactly the two failed deliveries retry, in every schedule"
    );
    assert!(st.hazard_drains_min >= 1, "the chain still drains its writer first");
}

/// An exhausted retry budget settles as LaunchFailed — after exactly
/// `retry_limit` redispatches, never more (no retry storm), never a
/// poison — and the stream stays usable for the follow-up launch.
#[test]
fn exhausted_retry_budget_fails_without_retrying_forever() {
    let sc = Scenario {
        bufs: 6,
        tiles_per_launch: 2,
        ops: vec![Op::Enqueue(0, 1, 2), Op::Wait, Op::Enqueue(3, 4, 5), Op::Wait],
        outcomes: vec![vec![Outcome::Fail, Outcome::Ok]],
        retry_limit: 1,
        eager_writeback: false,
    };
    let st = explore(&sc);
    assert!(st.violations.is_empty(), "violations: {:?}", st.violations);
    assert_eq!(st.leaked_max, 0, "every delivery's buffer comes home, retried or not");
    assert_eq!((st.retries_min, st.retries_max), (1, 1), "the budget bounds the redispatches");
    assert!(st.errors_seen.iter().any(|e| e.starts_with("LaunchFailed")), "{:?}", st.errors_seen);
    assert!(
        !st.errors_seen.iter().any(|e| e == "Poisoned"),
        "budget exhaustion is a launch failure, not poison: {:?}",
        st.errors_seen
    );
}

/// A flaky tile that heals while an independent launch pipelines behind
/// it: retries stay confined to the front launch's retirement, the
/// disjoint launch overlaps it un-drained, and both complete cleanly in
/// every schedule.
#[test]
fn retries_do_not_stall_the_pipeline() {
    let sc = Scenario {
        bufs: 6,
        tiles_per_launch: 2,
        ops: vec![Op::Enqueue(0, 1, 2), Op::Enqueue(3, 4, 5), Op::Wait],
        outcomes: vec![vec![Outcome::Flaky(1), Outcome::Ok]],
        retry_limit: 2,
        eager_writeback: false,
    };
    let st = explore(&sc);
    assert!(st.violations.is_empty(), "violations: {:?}", st.violations);
    assert!(st.errors_seen.is_empty(), "errors: {:?}", st.errors_seen);
    assert_eq!(st.inflight_max, 2, "a retrying front launch must not block pipelining");
    assert_eq!(st.hazard_drains_max, 0, "disjoint sets never force a drain");
    assert_eq!((st.retries_min, st.retries_max), (1, 1));
    assert_eq!(st.leaked_max, 0);
}

/// `download(x)` retires exactly through the last writer of `x`;
/// launches writing other buffers keep flowing (they are retired by the
/// final `Wait`, not the download).
#[test]
fn download_drains_only_its_writers_prefix() {
    let sc = Scenario {
        bufs: 6,
        tiles_per_launch: 2,
        // L0 writes 2, L1 writes 5; downloading 2 must not retire L1.
        ops: vec![Op::Enqueue(0, 1, 2), Op::Enqueue(3, 4, 5), Op::Download(2), Op::Wait],
        outcomes: vec![],
        retry_limit: 0,
        eager_writeback: false,
    };
    let st = explore(&sc);
    assert!(st.violations.is_empty(), "violations: {:?}", st.violations);
    assert_eq!(st.inflight_max, 2);
    assert!(st.errors_seen.is_empty(), "errors: {:?}", st.errors_seen);
}

/// A three-launch mixed pipeline: overlap where buffer sets are
/// disjoint, drain where they are not, all invariants under every
/// schedule.  This is the largest state space in the file; keep tile
/// counts small — DFS cost is factorial in the number of worker events.
#[test]
fn mixed_pipeline_holds_every_invariant() {
    let sc = Scenario {
        bufs: 7,
        tiles_per_launch: 2,
        ops: vec![
            Op::Enqueue(0, 1, 2), // L0 writes 2
            Op::Enqueue(3, 4, 5), // L1 disjoint: overlaps L0
            Op::Enqueue(2, 4, 6), // L2 reads 2: drains L0, L1 keeps flying
            Op::Wait,
        ],
        outcomes: vec![],
        retry_limit: 0,
        eager_writeback: false,
    };
    let st = explore(&sc);
    assert!(st.violations.is_empty(), "violations: {:?}", st.violations);
    assert!(st.inflight_max >= 2, "L0/L1 must overlap");
    assert!(st.hazard_drains_min >= 1, "L2 must drain its producer");
    assert_eq!(st.leaked_max, 0);
    assert!(st.errors_seen.is_empty(), "errors: {:?}", st.errors_seen);
}

/// Pin the scenario table's defaulting: outcomes absent from the table
/// are `Ok` (so most scenarios only spell out their faults).
#[test]
fn scenario_outcomes_default_to_ok() {
    let sc = Scenario {
        bufs: 1,
        tiles_per_launch: 1,
        ops: vec![],
        outcomes: vec![vec![Outcome::Fail]],
        retry_limit: 0,
        eager_writeback: false,
    };
    assert_eq!(sc.outcome(0, 0), Outcome::Fail);
    assert_eq!(sc.outcome(0, 9), Outcome::Ok);
    assert_eq!(sc.outcome(7, 0), Outcome::Ok);
}
