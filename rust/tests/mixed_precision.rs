//! Mixed-precision device streams (ISSUE 10): randomized schedules of
//! interleaved dependent and independent launches at every loaded width,
//! pinned **bit-identical per width** to the serial softfloat reference,
//! with transient fault injection riding the same schedules.
//!
//! Width selection honors `APFP_WIDTHS` through the default config, so
//! the CI widths matrix (single-width 512, single-width 1024, mixed
//! 128+512) drives these exact schedules over differently-provisioned
//! devices.  Line-mirrored by `python/tests/test_mixed_precision.py`,
//! which runs the same schedules against the Python port without a Rust
//! toolchain.

use apfp::baseline;
use apfp::config::{ApfpConfig, FaultSpec, RetryPolicy};
use apfp::coordinator::{Device, Matrix, StreamError};
use apfp::runtime::BackendKind;
use apfp::softfloat::prec_for_bits;
use apfp::testkit::Rng;

/// A builtin-manifest device over every width the config loads.  Honors
/// `APFP_BACKEND` for native and sim (xla cannot run artifact-less).
fn multi_width_device(cus: usize, faults: FaultSpec) -> Device {
    let backend = match BackendKind::from_env() {
        BackendKind::Xla => BackendKind::Native,
        b => b,
    };
    let cfg = ApfpConfig {
        backend,
        compute_units: cus,
        faults,
        retry: RetryPolicy { backoff_ms: 0, ..Default::default() },
        ..Default::default()
    };
    let dir = std::env::temp_dir().join("apfp_mixed_precision_no_artifacts/none");
    Device::new(cfg, &dir).expect("builtin-manifest device must open on a clean checkout")
}

/// One width's worth of schedule state: the device handles and the host
/// shadow matrices the serial reference updates in lockstep.
struct Lane {
    bits: u32,
    ha: apfp::coordinator::BufId,
    hb: apfp::coordinator::BufId,
    hc1: apfp::coordinator::BufId,
    hc2: apfp::coordinator::BufId,
    a: Matrix,
    b: Matrix,
    c1: Matrix,
    c2: Matrix,
}

/// Drive `rounds` randomized rounds of launches over every loaded width:
/// each round enqueues, per width, two independent launches (disjoint C
/// buffers — these may pipeline freely, across widths too) and, half the
/// time, a dependent chain step reading the C it writes.  The host
/// shadow runs the identical schedule through `gemm_serial`, so the
/// final download must be bit-identical per width.
fn run_schedule(dev: &Device, seed: u64, rounds: usize) {
    let widths = ApfpConfig::default().effective_widths();
    let mut rng = Rng::from_seed(seed);
    let (n, k, m) = (10usize, 8usize, 9usize);
    let mut s = dev.stream().expect("stream");
    let mut lanes: Vec<Lane> = widths
        .iter()
        .map(|&bits| {
            let prec = prec_for_bits(bits);
            let a = Matrix::random(n, k, prec, seed ^ u64::from(bits), 25);
            let b = Matrix::random(k, m, prec, seed ^ u64::from(bits) ^ 1, 25);
            let c1 = Matrix::random(n, m, prec, seed ^ u64::from(bits) ^ 2, 25);
            let c2 = Matrix::random(n, m, prec, seed ^ u64::from(bits) ^ 3, 25);
            Lane {
                bits,
                ha: s.upload(&a),
                hb: s.upload(&b),
                hc1: s.upload(&c1),
                hc2: s.upload(&c2),
                a,
                b,
                c1,
                c2,
            }
        })
        .collect();
    for _ in 0..rounds {
        // independent pair per width, interleaved across widths: these
        // have disjoint write sets and must be free to stay in flight
        for lane in &mut lanes {
            s.enqueue_gemm_at(lane.bits, lane.ha, lane.hb, lane.hc1).expect("independent 1");
            s.enqueue_gemm_at(lane.bits, lane.ha, lane.hb, lane.hc2).expect("independent 2");
            lane.c1 = baseline::gemm_serial(&lane.a, &lane.b, &lane.c1);
            lane.c2 = baseline::gemm_serial(&lane.a, &lane.b, &lane.c2);
        }
        // dependent chain step on a random width: reads the C it writes,
        // so the hazard tracker must drain that width's prior launches
        // (and only the conflicting prefix) before this one runs
        if rng.bool() {
            let pick = rng.below(lanes.len() as u64) as usize;
            let lane = &mut lanes[pick];
            s.enqueue_gemm_at(lane.bits, lane.hc1, lane.hb, lane.hc1).expect("dependent");
            lane.c1 = baseline::gemm_serial(&lane.c1, &lane.b, &lane.c1);
        }
    }
    s.wait().expect("drain");
    for lane in &lanes {
        assert_eq!(
            s.download(lane.hc1).expect("download c1"),
            lane.c1,
            "width {}: C1 must be bit-identical to the serial reference",
            lane.bits
        );
        assert_eq!(
            s.download(lane.hc2).expect("download c2"),
            lane.c2,
            "width {}: C2 must be bit-identical to the serial reference",
            lane.bits
        );
    }
}

#[test]
fn randomized_mixed_width_schedules_are_bit_identical_per_width() {
    let dev = multi_width_device(2, FaultSpec::default());
    for seed in [11u64, 23, 47] {
        run_schedule(&dev, seed, 4);
    }
    // the independent launches must actually have pipelined — with two
    // or more loaded widths that overlap spans launches of *different*
    // widths in flight on one device simultaneously
    let metrics = dev.metrics();
    assert!(
        metrics.inflight_max >= 2,
        "independent mixed-width launches must overlap (inflight_max {})",
        metrics.inflight_max
    );
    assert_eq!(
        (metrics.retries, metrics.respawns, metrics.quarantined_cus),
        (0, 0, 0),
        "a fault-free schedule must never touch the healing ladder"
    );
}

#[test]
fn transient_faults_heal_inside_mixed_width_schedules() {
    // tile (0,0) exists in every launch of the schedule, whatever the
    // width: fail its first attempt every time, so the retry rung runs
    // constantly while widths interleave — results must stay
    // bit-identical per width and the stream must never poison
    let dev = multi_width_device(
        2,
        FaultSpec { fail_tile: Some((0, 0)), fail_attempts: Some(1), ..Default::default() },
    );
    run_schedule(&dev, 61, 3);
    let metrics = dev.metrics();
    assert!(metrics.retries > 0, "the injected fault must have forced redispatches");
    assert_eq!(metrics.respawns, 0, "tile errors never respawn workers");
}

#[test]
fn width_mismatch_and_unloaded_width_stay_typed_under_load() {
    let dev = multi_width_device(1, FaultSpec::default());
    let widths = ApfpConfig::default().effective_widths();
    let prec = prec_for_bits(widths[0]);
    let mut s = dev.stream().expect("stream");
    let ha = s.upload(&Matrix::random(4, 4, prec, 5, 20));
    let hb = s.upload(&Matrix::random(4, 4, prec, 6, 20));
    // a buffer at some other loaded width (or a fresh conversion if the
    // device is single-width) must be rejected as C with a typed error
    let other = widths.get(1).copied().unwrap_or(widths[0] + 64);
    let hc = s.alloc_at(other, 4, 4);
    let err = s.enqueue_gemm_at(widths[0], ha, hb, hc).expect_err("mismatched C width");
    match err.downcast_ref::<StreamError>() {
        Some(StreamError::WidthMismatch { bits, c, .. }) => {
            assert_eq!((*bits, *c), (widths[0], other));
        }
        other => panic!("expected WidthMismatch, got {other:?}"),
    }
    // an unloaded width is the typed manifest error naming what is loaded
    let unloaded = (1..)
        .map(|i| 128 + 64 * i)
        .find(|w| !widths.contains(w))
        .expect("some width is unloaded");
    let err = s.enqueue_gemm_at(unloaded, ha, hb, hc).expect_err("unloaded width");
    let me = err
        .downcast_ref::<apfp::runtime::manifest::ManifestError>()
        .expect("typed ManifestError");
    match me {
        apfp::runtime::manifest::ManifestError::NoArtifact { bits, loaded, .. } => {
            assert_eq!(*bits, unloaded);
            assert_eq!(loaded, &dev.widths());
        }
        other => panic!("expected NoArtifact, got {other:?}"),
    }
    // neither error poisoned anything: the stream still launches and
    // converts across widths
    let hc_ok = s.convert(hc, widths[0]).expect("convert");
    s.enqueue_gemm_at(widths[0], ha, hb, hc_ok).expect("enqueue after typed errors");
    s.wait().expect("wait");
    let want = baseline::gemm_serial(
        &s.download(ha).expect("a"),
        &s.download(hb).expect("b"),
        &Matrix::zeros(4, 4, prec),
    );
    assert_eq!(s.download(hc_ok).expect("c"), want);
}
