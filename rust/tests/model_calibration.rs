//! Calibration goldens for the hardware model: the paper's published
//! design points (Tab. I-III, Fig. 3/5/6 of "Fast Arbitrary Precision
//! Floating Point on FPGA") pinned with explicit tolerances, the
//! `model_golden.json` perf-regression file checked against the live
//! model with the same comparator `repro modelgold --check` uses — and a
//! falsifiability case proving the gate actually *can* trip: a ±20%
//! perturbation of `PIPELINE_DEPTH` pushed through the model must exceed
//! the gate tolerance on every affected key.
//!
//! Mirrored line-for-line (formulas, constants, rounding) by
//! `python/tests/test_sim_backend.py`, which regenerates the golden file
//! on toolchain-less checkouts.

use std::collections::HashMap;

use apfp::hwmodel::{dsp, resources, u250, DesignPoint};
use apfp::runtime::manifest::{self, ArtifactKind, TileShape};
use apfp::runtime::sim_backend::tile_cost;
use apfp::sim::gemm_sim;

/// The gate comparator, verbatim from `repro modelgold --check`.
const REL_TOL: f64 = 1e-6;

fn gate_trips(pinned: f64, got: f64) -> bool {
    let scale = pinned.abs().max(got.abs()).max(1e-30);
    (got - pinned).abs() / scale > REL_TOL
}

fn builtin_gemm_meta(bits: u32) -> manifest::ArtifactMeta {
    manifest::builtin(bits, TileShape::default())
        .expect("builtin manifest")
        .into_iter()
        .find(|m| m.kind == ArtifactKind::Gemm)
        .expect("builtin gemm meta")
}

/// The exact key set `repro modelgold` pins (and `model_golden.json`
/// stores) — recomputed from the live model.
fn model_golden_values() -> Vec<(String, f64)> {
    let mut out = Vec::new();
    for bits in [512u32, 1024] {
        let c = tile_cost(&builtin_gemm_meta(bits));
        out.push((format!("tile{bits}_cycles"), c.cycles as f64));
        out.push((format!("tile{bits}_macs"), c.macs as f64));
        out.push((format!("tile{bits}_dram_bytes"), c.dram_bytes as f64));
        out.push((format!("tile{bits}_compute_ps"), c.compute_ps as f64));
        out.push((format!("tile{bits}_mem_ps"), c.mem_ps as f64));
        out.push((format!("tile{bits}_energy_pj"), c.energy_pj as f64));
    }
    for (bits, cus) in [(512u32, 1usize), (512, 2), (512, 4), (512, 8), (1024, 1)] {
        let d = if bits == 512 { DesignPoint::gemm_512(cus) } else { DesignPoint::gemm_1024(cus) };
        let s = d.synthesize();
        out.push((format!("gemm{bits}_cu{cus}_freq_mhz"), s.frequency_mhz));
        out.push((format!("gemm{bits}_cu{cus}_peak_mmacs"), gemm_sim::peak(&d, 32).mmacs / 1e6));
        let p = gemm_sim::simulate(&d, 4096, 32, 32);
        out.push((format!("gemm{bits}_cu{cus}_n4096_mmacs"), p.mmacs / 1e6));
        out.push((format!("gemm{bits}_cu{cus}_n4096_efficiency"), p.efficiency));
    }
    out.sort_by(|a, b| a.0.cmp(&b.0));
    out
}

/// Minimal parser for the flat `model_golden.json` format (the same
/// line discipline `repro modelgold --write` emits).
fn parse_golden(text: &str) -> HashMap<String, f64> {
    let mut out = HashMap::new();
    for line in text.lines() {
        let line = line.trim().trim_end_matches(',');
        let Some(rest) = line.strip_prefix('"') else { continue };
        let Some((key, val)) = rest.split_once("\":") else { continue };
        let v: f64 = val.trim().parse().expect("golden value parses as f64");
        out.insert(key.to_string(), v);
    }
    out
}

// -- paper pins (Tab. I-III, Fig. 3) ------------------------------------

#[test]
fn tab1_mult512_resources_and_frequency() {
    // Tab. I: 512-bit multiplier, 72-bit bottom-out — 27 leaves of 56
    // bits, 432 DSPs (~4% of the U250's 12288), ~456 MHz standalone
    assert_eq!(dsp::karatsuba_leaves(448, 72), (27, 56));
    assert_eq!(dsp::multiplier_dsps(448, 72), 432);
    assert!(dsp::multiplier_dsps(448, 72) * 100 / u250::DSP_TOTAL <= 4);
    let s = DesignPoint::mult_512(1).synthesize();
    assert!(s.failure.is_none());
    assert!((s.frequency_mhz - 456.0).abs() < 20.0, "Tab I freq: {}", s.frequency_mhz);
}

#[test]
fn tab2_mult1024_scales_by_karatsuba_not_quadratic() {
    // Tab. II: doubling precision costs 3x leaves (81 of 60 bits), not 4x
    assert_eq!(dsp::karatsuba_leaves(960, 72).0, 81);
    let d512 = dsp::multiplier_dsps(448, 72) as f64;
    let d1024 = dsp::multiplier_dsps(960, 72) as f64;
    assert!(d1024 / d512 < 4.0, "Karatsuba must beat schoolbook scaling");
    let s = DesignPoint::mult_1024(1).synthesize();
    assert!(s.failure.is_none());
    assert!(s.frequency_mhz > 250.0, "Tab II freq: {}", s.frequency_mhz);
}

#[test]
fn tab3_gemm_design_points() {
    // Tab. III rows: frequency and peak throughput per CU count, with the
    // same tolerances the sim unit tests use (model, not gospel: 18%)
    for (cus, paper_mmacs) in [(1usize, 322.0f64), (2, 540.0), (4, 1049.0), (8, 2002.0)] {
        let d = DesignPoint::gemm_512(cus);
        let s = d.synthesize();
        assert!(s.failure.is_none(), "{cus} CUs must synthesize");
        assert!(
            (250.0..=340.0).contains(&s.frequency_mhz),
            "{cus} CU freq out of Tab III band: {}",
            s.frequency_mhz
        );
        let got = gemm_sim::peak(&d, 32).mmacs / 1e6;
        let rel = (got - paper_mmacs).abs() / paper_mmacs;
        assert!(rel < 0.18, "{cus} CUs: {got:.0} vs paper {paper_mmacs} ({rel:.2} rel)");
    }
    // Fig. 6 analog: the 1024-bit design lands near 158 MMAC/s
    let got = gemm_sim::peak(&DesignPoint::gemm_1024(1), 32).mmacs / 1e6;
    assert!((got - 158.0).abs() / 158.0 < 0.35, "1024-bit peak: {got:.0}");
}

#[test]
fn fig3_crossover_shape() {
    // Fig. 5's roofline shape: paper tiles (32x32) are compute-bound,
    // skinny tiles (4x4) flip memory-bound; throughput grows with N
    let d = DesignPoint::gemm_512(8);
    let wide = gemm_sim::simulate(&d, 8192, 32, 32);
    assert!(wide.compute_s > wide.mem_s, "32x32 tiles must be compute-bound");
    let skinny = gemm_sim::simulate(&d, 8192, 4, 4);
    assert!(skinny.mem_s > skinny.compute_s, "4x4 tiles must be memory-bound");
    let small = gemm_sim::simulate(&d, 512, 32, 32);
    assert!(wide.mmacs > small.mmacs, "fixed costs must amortize with N");
}

#[test]
fn tile_cost_anchors_hand_derived() {
    // The 512-bit walk-through from sim_backend.rs's docs: 13634 CLBs
    // keeps II=1, so a 32x32x32 K-step is 32768 MACs + 400 fill cycles
    assert_eq!(resources::cu_clbs(&DesignPoint::gemm_512(1)), 13_634);
    let c = tile_cost(&builtin_gemm_meta(512));
    assert_eq!(c.macs, 32_768);
    assert_eq!(c.cycles, 33_168);
    assert_eq!(c.dram_bytes, 3 * 32 * 32 * 64);
    assert!(c.compute_ps > c.mem_ps, "paper tile is compute-bound per CU too");
}

// -- the regression gate itself -----------------------------------------

#[test]
fn model_golden_file_matches_the_live_model() {
    // the same check `repro modelgold --check` (CI analysis job) runs,
    // as a cargo test so a model edit cannot land without regenerating
    // the goldens — see docs/ARCHITECTURE.md for the regeneration recipe
    let text = std::fs::read_to_string(concat!(env!("CARGO_MANIFEST_DIR"), "/model_golden.json"))
        .expect("rust/model_golden.json is committed");
    let pinned = parse_golden(&text);
    let live = model_golden_values();
    assert_eq!(pinned.len(), live.len(), "golden key count");
    for (key, got) in &live {
        let want = pinned
            .get(key)
            .unwrap_or_else(|| panic!("golden file is missing {key}: regenerate it"));
        assert!(
            !gate_trips(*want, *got),
            "{key} drifted: pinned {want}, model computes {got} — \
             regenerate with `repro modelgold --write` or revert the model change"
        );
    }
}

#[test]
fn perturbed_pipeline_depth_trips_the_gate() {
    // Falsifiability: if PIPELINE_DEPTH were edited by ±20%, the gate
    // comparator must flag the drift on the cycle-derived keys.  The
    // perturbed value is reconstructed from the pinned cycles (cycles =
    // macs * II + depth, II = 1 at 512 bits), so this exercises exactly
    // the arithmetic a constant edit would change.
    let c = tile_cost(&builtin_gemm_meta(512));
    let base_cycles = c.cycles as f64;
    for scale in [0.8f64, 1.2] {
        let perturbed = base_cycles - gemm_sim::PIPELINE_DEPTH + gemm_sim::PIPELINE_DEPTH * scale;
        assert!(
            gate_trips(base_cycles, perturbed),
            "a {scale}x PIPELINE_DEPTH must move tile512_cycles past the 1e-6 gate: \
             {base_cycles} -> {perturbed}"
        );
        // and the drift is orders of magnitude above the tolerance, so
        // float noise can never mask it
        let rel = (perturbed - base_cycles).abs() / base_cycles;
        assert!(rel > 1e-3, "perturbation headroom: {rel}");
    }
    // an unperturbed recomputation, by contrast, sits exactly on the pin
    let again = tile_cost(&builtin_gemm_meta(512));
    assert!(!gate_trips(base_cycles, again.cycles as f64));
}
