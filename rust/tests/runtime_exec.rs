//! Integration: execute real AOT artifacts through PJRT and bit-compare
//! against the softfloat reference — the reproduction's analog of the
//! paper's "output compared to the equivalent MPFR software computation".
//!
//! Requires `make artifacts` to have run (skipped otherwise).

use apfp::pack::PlaneBatch;
use apfp::runtime::{default_artifact_dir, Runtime};
use apfp::softfloat::ApFloat;
use apfp::testkit::Rng;

fn artifact_dir() -> Option<std::path::PathBuf> {
    let d = default_artifact_dir();
    d.join("manifest.txt").exists().then_some(d)
}

fn rand_ap(rng: &mut Rng, prec: u32) -> ApFloat {
    let n = (prec / 64) as usize;
    let mut mant = rng.limbs(n);
    mant[n - 1] |= 1 << 63;
    ApFloat::from_parts(rng.bool(), rng.range_i64(-900, 900), mant, prec)
}

#[test]
fn mul_stream_bit_exact_512() {
    let Some(dir) = artifact_dir() else { eprintln!("skipped: no artifacts"); return };
    let rt = Runtime::new(&dir).unwrap();
    let mut rng = Rng::from_seed(1);
    let n = 100; // exercises chunking (batch is 64) and padding
    let a: Vec<ApFloat> = (0..n).map(|_| rand_ap(&mut rng, 448)).collect();
    let mut b: Vec<ApFloat> = (0..n).map(|_| rand_ap(&mut rng, 448)).collect();
    b[7] = ApFloat::zero(448); // zero lane
    let got = rt
        .exec_stream_binop("mul_512", &PlaneBatch::from_slice(&a, 448), &PlaneBatch::from_slice(&b, 448))
        .unwrap()
        .to_vec();
    for i in 0..n {
        assert_eq!(got[i], a[i].mul(&b[i]), "lane {i}");
    }
}

#[test]
fn add_stream_bit_exact_512() {
    let Some(dir) = artifact_dir() else { eprintln!("skipped: no artifacts"); return };
    let rt = Runtime::new(&dir).unwrap();
    let mut rng = Rng::from_seed(2);
    let n = 64;
    let a: Vec<ApFloat> = (0..n).map(|_| rand_ap(&mut rng, 448)).collect();
    let mut b: Vec<ApFloat> = (0..n).map(|_| rand_ap(&mut rng, 448)).collect();
    b[3] = a[3].neg(); // exact cancellation lane
    let got = rt
        .exec_stream_binop("add_512", &PlaneBatch::from_slice(&a, 448), &PlaneBatch::from_slice(&b, 448))
        .unwrap()
        .to_vec();
    for i in 0..n {
        assert_eq!(got[i], a[i].add(&b[i]), "lane {i}");
    }
}

#[test]
fn mac_stream_bit_exact_1024() {
    let Some(dir) = artifact_dir() else { eprintln!("skipped: no artifacts"); return };
    let rt = Runtime::new(&dir).unwrap();
    let mut rng = Rng::from_seed(3);
    let n = 32;
    let c: Vec<ApFloat> = (0..n).map(|_| rand_ap(&mut rng, 960)).collect();
    let a: Vec<ApFloat> = (0..n).map(|_| rand_ap(&mut rng, 960)).collect();
    let b: Vec<ApFloat> = (0..n).map(|_| rand_ap(&mut rng, 960)).collect();
    let got = rt
        .exec_stream_mac(
            "mac_1024",
            &PlaneBatch::from_slice(&c, 960),
            &PlaneBatch::from_slice(&a, 960),
            &PlaneBatch::from_slice(&b, 960),
        )
        .unwrap()
        .to_vec();
    for i in 0..n {
        assert_eq!(got[i], c[i].mac(&a[i], &b[i]), "lane {i}");
    }
}

#[test]
fn gemm_tile_bit_exact_512() {
    let Some(dir) = artifact_dir() else { eprintln!("skipped: no artifacts"); return };
    let rt = Runtime::new(&dir).unwrap();
    let meta = rt.meta("gemm_512_t8").unwrap().clone();
    let (tn, tm, kt) = (meta.t_n, meta.t_m, meta.k_tile);
    let mut rng = Rng::from_seed(4);
    let a: Vec<ApFloat> = (0..tn * kt).map(|_| rand_ap(&mut rng, 448)).collect();
    let b: Vec<ApFloat> = (0..kt * tm).map(|_| rand_ap(&mut rng, 448)).collect();
    let c: Vec<ApFloat> = (0..tn * tm).map(|_| rand_ap(&mut rng, 448)).collect();
    let got = rt
        .exec_gemm_tile(
            "gemm_512_t8",
            &PlaneBatch::from_slice(&a, 448),
            &PlaneBatch::from_slice(&b, 448),
            &PlaneBatch::from_slice(&c, 448),
        )
        .unwrap()
        .to_vec();
    // reference: sequential K accumulation with intermediate rounding
    for i in 0..tn {
        for j in 0..tm {
            let mut acc = c[i * tm + j].clone();
            for k in 0..kt {
                acc = acc.mac(&a[i * kt + k], &b[k * tm + j]);
            }
            assert_eq!(got[i * tm + j], acc, "tile element ({i},{j})");
        }
    }
}
