//! Integration: execute artifacts through the runtime's pluggable backend
//! and bit-compare against the softfloat reference — the reproduction's
//! analog of the paper's "output compared to the equivalent MPFR software
//! computation".
//!
//! On the default native backend these tests run on every checkout (the
//! builtin manifest is synthesized when `artifacts/` is absent).  With
//! `APFP_BACKEND=xla` they additionally need `make artifacts` + a real xla
//! crate, and skip cleanly when the runtime cannot come up.

use apfp::pack::PlaneBatch;
use apfp::runtime::{default_artifact_dir, ArtifactKind, BackendKind, Runtime};
use apfp::softfloat::ApFloat;
use apfp::testkit::Rng;

fn runtime() -> Option<Runtime> {
    let kind = BackendKind::from_env();
    match Runtime::new(&default_artifact_dir()) {
        Ok(rt) => Some(rt),
        // the xla backend legitimately skips without artifacts; the native
        // backend must come up on every checkout — a failure there is a
        // real regression, never a skip
        Err(e) if kind == BackendKind::Xla => {
            eprintln!("skipped: {e:#}");
            None
        }
        Err(e) => panic!("native runtime must open on a clean checkout: {e:#}"),
    }
}

fn rand_ap(rng: &mut Rng, prec: u32) -> ApFloat {
    let n = (prec / 64) as usize;
    let mut mant = rng.limbs(n);
    mant[n - 1] |= 1 << 63;
    ApFloat::from_parts(rng.bool(), rng.range_i64(-900, 900), mant, prec)
}

#[test]
fn mul_stream_bit_exact_512() {
    let Some(rt) = runtime() else { return };
    let mut rng = Rng::from_seed(1);
    let n = 100; // exercises chunking (stream batch is 64) and padding
    let a: Vec<ApFloat> = (0..n).map(|_| rand_ap(&mut rng, 448)).collect();
    let mut b: Vec<ApFloat> = (0..n).map(|_| rand_ap(&mut rng, 448)).collect();
    b[7] = ApFloat::zero(448); // zero lane
    let got = rt
        .exec_stream_binop("mul_512", &PlaneBatch::from_slice(&a, 448), &PlaneBatch::from_slice(&b, 448))
        .unwrap()
        .to_vec();
    for i in 0..n {
        assert_eq!(got[i], a[i].mul(&b[i]), "lane {i}");
    }
}

#[test]
fn add_stream_bit_exact_512() {
    let Some(rt) = runtime() else { return };
    let mut rng = Rng::from_seed(2);
    let n = 64;
    let a: Vec<ApFloat> = (0..n).map(|_| rand_ap(&mut rng, 448)).collect();
    let mut b: Vec<ApFloat> = (0..n).map(|_| rand_ap(&mut rng, 448)).collect();
    b[3] = a[3].neg(); // exact cancellation lane
    let got = rt
        .exec_stream_binop("add_512", &PlaneBatch::from_slice(&a, 448), &PlaneBatch::from_slice(&b, 448))
        .unwrap()
        .to_vec();
    for i in 0..n {
        assert_eq!(got[i], a[i].add(&b[i]), "lane {i}");
    }
}

#[test]
fn mac_stream_bit_exact_1024() {
    let Some(rt) = runtime() else { return };
    let mut rng = Rng::from_seed(3);
    let n = 32;
    let c: Vec<ApFloat> = (0..n).map(|_| rand_ap(&mut rng, 960)).collect();
    let a: Vec<ApFloat> = (0..n).map(|_| rand_ap(&mut rng, 960)).collect();
    let b: Vec<ApFloat> = (0..n).map(|_| rand_ap(&mut rng, 960)).collect();
    let got = rt
        .exec_stream_mac(
            "mac_1024",
            &PlaneBatch::from_slice(&c, 960),
            &PlaneBatch::from_slice(&a, 960),
            &PlaneBatch::from_slice(&b, 960),
        )
        .unwrap()
        .to_vec();
    for i in 0..n {
        assert_eq!(got[i], c[i].mac(&a[i], &b[i]), "lane {i}");
    }
}

#[test]
fn gemm_tile_bit_exact_512() {
    let Some(rt) = runtime() else { return };
    let meta = rt.find(ArtifactKind::Gemm, 512).unwrap().clone();
    let (tn, tm, kt) = (meta.t_n, meta.t_m, meta.k_tile);
    let mut rng = Rng::from_seed(4);
    let a: Vec<ApFloat> = (0..tn * kt).map(|_| rand_ap(&mut rng, 448)).collect();
    let b: Vec<ApFloat> = (0..kt * tm).map(|_| rand_ap(&mut rng, 448)).collect();
    let c: Vec<ApFloat> = (0..tn * tm).map(|_| rand_ap(&mut rng, 448)).collect();
    let mut got = PlaneBatch::from_slice(&c, 448);
    rt.exec_gemm_tile(
        &meta.name,
        &PlaneBatch::from_slice(&a, 448),
        &PlaneBatch::from_slice(&b, 448),
        &mut got,
    )
    .unwrap();
    let got = got.to_vec();
    // reference: sequential K accumulation with intermediate rounding
    for i in 0..tn {
        for j in 0..tm {
            let mut acc = c[i * tm + j].clone();
            for k in 0..kt {
                acc = acc.mac(&a[i * kt + k], &b[k * tm + j]);
            }
            assert_eq!(got[i * tm + j], acc, "tile element ({i},{j})");
        }
    }
}

#[test]
fn gemm_tile_k_steps_accumulate_in_place_1024() {
    // Two artifact invocations against the same C planes — the §III
    // K-step loop the worker runs — must equal one long mac chain.
    let Some(rt) = runtime() else { return };
    let meta = rt.find(ArtifactKind::Gemm, 1024).unwrap().clone();
    let (tn, tm, kt) = (meta.t_n, meta.t_m, meta.k_tile);
    let mut rng = Rng::from_seed(5);
    let a1: Vec<ApFloat> = (0..tn * kt).map(|_| rand_ap(&mut rng, 960)).collect();
    let a2: Vec<ApFloat> = (0..tn * kt).map(|_| rand_ap(&mut rng, 960)).collect();
    let b1: Vec<ApFloat> = (0..kt * tm).map(|_| rand_ap(&mut rng, 960)).collect();
    let b2: Vec<ApFloat> = (0..kt * tm).map(|_| rand_ap(&mut rng, 960)).collect();
    let c: Vec<ApFloat> = (0..tn * tm).map(|_| rand_ap(&mut rng, 960)).collect();
    let mut got = PlaneBatch::from_slice(&c, 960);
    for (a, b) in [(&a1, &b1), (&a2, &b2)] {
        rt.exec_gemm_tile(
            &meta.name,
            &PlaneBatch::from_slice(a, 960),
            &PlaneBatch::from_slice(b, 960),
            &mut got,
        )
        .unwrap();
    }
    for i in 0..tn {
        for j in 0..tm {
            let mut acc = c[i * tm + j].clone();
            for (a, b) in [(&a1, &b1), (&a2, &b2)] {
                for k in 0..kt {
                    acc = acc.mac(&a[i * kt + k], &b[k * tm + j]);
                }
            }
            assert_eq!(got.get(i * tm + j), acc, "tile element ({i},{j})");
        }
    }
}
