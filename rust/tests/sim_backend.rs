//! Differential tests for the simulated backend (`APFP_BACKEND=sim`):
//! the same device stack, the same launches, on `SimBackend` vs
//! `NativeBackend` — outputs must be bit-identical (sim delegates tile
//! math to the very same arena kernels) while the hardware-model ledger
//! lights up on sim only.
//!
//! The fault-injection half pins the model-counter conservation invariant
//! (`docs/INVARIANTS.md`): a transient tile failure, a worker death with
//! respawn, and a failed launch must leave the ledger exactly where a
//! fault-free run of the same workload puts it — retried attempts are
//! never double-counted, failed launches contribute nothing.

use std::time::Duration;

use apfp::baseline;
use apfp::config::{ApfpConfig, FaultSpec, RetryPolicy};
use apfp::coordinator::{Device, Matrix, ModelMetricsSnapshot};
use apfp::runtime::BackendKind;

fn device(backend: BackendKind, cus: usize, faults: FaultSpec) -> Device {
    let cfg = ApfpConfig {
        backend,
        compute_units: cus,
        tile_n: 4,
        tile_m: 4,
        tile_k: 4,
        faults,
        retry: RetryPolicy { backoff_ms: 0, ..Default::default() },
        reply_timeout: Duration::from_millis(25),
        ..Default::default()
    };
    // guaranteed-absent artifact dir: both backends serve the builtin
    // manifest, so the differential runs on any checkout
    let dir = std::env::temp_dir().join("apfp_sim_backend_no_artifacts/none");
    Device::new(cfg, &dir).expect("builtin-manifest device must open on a clean checkout")
}

/// Run `C += A @ B` launches on a fresh device and return the output and
/// the model-ledger snapshot.
fn run_gemm(dev: &Device, n: usize, k: usize, m: usize, seed: u64) -> (Matrix, ModelMetricsSnapshot) {
    let a = Matrix::random(n, k, 448, seed, 30);
    let b = Matrix::random(k, m, 448, seed + 1, 30);
    let c = Matrix::random(n, m, 448, seed + 2, 30);
    let (out, _) = dev.gemm(&a, &b, &c).expect("gemm");
    (out, dev.model_metrics())
}

#[test]
fn sim_is_bit_identical_to_native_across_shapes() {
    // non-divisible edges, multi-CU bands, single-row degenerates
    for (i, &(n, k, m, cus)) in [(8, 8, 8, 1), (7, 5, 9, 2), (1, 6, 11, 2), (12, 3, 4, 3)]
        .iter()
        .enumerate()
    {
        let seed = 100 + 10 * i as u64;
        let sim = device(BackendKind::Sim, cus, FaultSpec::default());
        let native = device(BackendKind::Native, cus, FaultSpec::default());
        let (sim_out, sim_m) = run_gemm(&sim, n, k, m, seed);
        let (native_out, native_m) = run_gemm(&native, n, k, m, seed);

        assert_eq!(sim_out, native_out, "{n}x{k}x{m} on {cus} CUs");
        // and both equal the serial softfloat baseline
        let a = Matrix::random(n, k, 448, seed, 30);
        let b = Matrix::random(k, m, 448, seed + 1, 30);
        let c = Matrix::random(n, m, 448, seed + 2, 30);
        assert_eq!(sim_out, baseline::gemm_serial(&a, &b, &c));

        // the ledger is the only observable difference between backends
        assert!(sim_m.is_live(), "sim ledger must record the launch");
        assert!(sim_m.cycles > 0 && sim_m.dram_bytes > 0 && sim_m.energy_pj > 0);
        assert!(sim_m.total_s() > 0.0 && sim_m.efficiency() > 0.0 && sim_m.efficiency() <= 1.0);
        assert!(!native_m.is_live(), "native ledger must stay all-zero");
    }
}

#[test]
fn sim_stream_ops_match_softfloat() {
    let dev = device(BackendKind::Sim, 2, FaultSpec::default());
    let a = Matrix::random(1, 40, 448, 70, 60);
    let b = Matrix::random(1, 40, 448, 71, 60);
    let c = Matrix::random(1, 40, 448, 72, 60);
    let got = dev.mul_stream(a.values(), b.values()).expect("mul stream");
    for (i, g) in got.iter().enumerate() {
        assert_eq!(*g, a.values()[i].mul(&b.values()[i]), "mul lane {i}");
    }
    let got = dev.add_stream(a.values(), b.values()).expect("add stream");
    for (i, g) in got.iter().enumerate() {
        assert_eq!(*g, a.values()[i].add(&b.values()[i]), "add lane {i}");
    }
    let got = dev.mac_stream(c.values(), a.values(), b.values()).expect("mac stream");
    for (i, g) in got.iter().enumerate() {
        assert_eq!(*g, c.values()[i].add(&a.values()[i].mul(&b.values()[i])), "mac lane {i}");
    }
    // stream operators are not part of the GEMM dataflow model: they
    // leave the ledger untouched (documented in sim_backend.rs)
    assert!(!dev.model_metrics().is_live());
}

/// Strip the volatile dimensions (none — every ledger field is modeled,
/// not measured) so two snapshots can be compared whole.
fn ledger_counts(m: &ModelMetricsSnapshot) -> (u64, u64, u64, u64, u64, u64, u64, u64) {
    (m.tiles, m.launches, m.cycles, m.macs, m.dram_bytes, m.compute_ps, m.mem_ps, m.energy_pj)
}

#[test]
fn transient_tile_failure_is_not_double_counted() {
    let (n, k, m) = (8, 8, 8); // tile origins (0|4, 0|4) on 4x4x4 tiles
    let clean = device(BackendKind::Sim, 2, FaultSpec::default());
    let (want_out, want_m) = run_gemm(&clean, n, k, m, 500);

    // first delivery of tile (0,4) fails, the retry lands
    let faults =
        FaultSpec { fail_tile: Some((0, 4)), fail_attempts: Some(1), ..Default::default() };
    let faulted = device(BackendKind::Sim, 2, faults);
    let (got_out, got_m) = run_gemm(&faulted, n, k, m, 500);

    assert_eq!(got_out, want_out, "recovered launch must stay bit-identical");
    assert!(faulted.metrics().retries >= 1, "the fault must actually have tripped");
    assert_eq!(
        ledger_counts(&got_m),
        ledger_counts(&want_m),
        "a retried tile is modeled exactly once: failed attempts accrue nothing"
    );
}

#[test]
fn worker_death_and_respawn_keep_the_ledger_conserved() {
    let (n, k, m) = (8, 8, 8);
    let clean = device(BackendKind::Sim, 2, FaultSpec::default());
    let (want_out, want_m) = run_gemm(&clean, n, k, m, 600);

    // first delivery of tile (4,0) kills its worker; the supervisor
    // respawns the CU and the redelivered tile survives
    let faults =
        FaultSpec { die_on_tile: Some((4, 0)), die_attempts: Some(1), ..Default::default() };
    let faulted = device(BackendKind::Sim, 2, faults);
    let (got_out, got_m) = run_gemm(&faulted, n, k, m, 600);

    assert_eq!(got_out, want_out, "respawned CU must stay bit-identical");
    assert!(faulted.metrics().respawns >= 1, "the death must actually have happened");
    assert_eq!(
        ledger_counts(&got_m),
        ledger_counts(&want_m),
        "a tile replayed through a respawn is modeled exactly once"
    );
}

#[test]
fn per_width_ledger_conserves_the_device_totals() {
    // one sim device hosting three widths; launches land on all of them.
    // Widths are pinned (not env-derived) so the schedule below is legal
    // under any APFP_WIDTHS override in the CI matrix.
    let cfg = ApfpConfig {
        backend: BackendKind::Sim,
        compute_units: 2,
        tile_n: 4,
        tile_m: 4,
        tile_k: 4,
        widths: vec![128, 512, 1024],
        ..Default::default()
    };
    let dir = std::env::temp_dir().join("apfp_sim_backend_no_artifacts/none");
    let dev = Device::new(cfg, &dir).expect("sim device");

    let (n, k, m) = (8usize, 8usize, 8usize);
    for (bits, launches) in [(128u32, 3usize), (512, 2), (1024, 1)] {
        let prec = bits - 64;
        let a = Matrix::random(n, k, prec, 800 + u64::from(bits), 30);
        let b = Matrix::random(k, m, prec, 801 + u64::from(bits), 30);
        let mut c = Matrix::zeros(n, m, prec);
        for _ in 0..launches {
            c = dev.gemm_at(bits, &a, &b, &c).expect("gemm_at").0;
        }
    }

    let snap = dev.model_metrics();
    assert!(snap.is_live());
    let by_width: Vec<_> = snap.width_breakdown().collect();
    assert_eq!(
        by_width.iter().map(|w| w.bits).collect::<Vec<_>>(),
        vec![128, 512, 1024],
        "every width that launched owns a ledger slot, in width order"
    );
    for (w, want_launches) in by_width.iter().zip([3u64, 2, 1]) {
        assert_eq!(w.launches, want_launches, "{} bits", w.bits);
        assert!(w.tiles > 0 && w.cycles > 0 && w.macs > 0 && w.energy_pj > 0);
    }
    // the conservation invariant (docs/INVARIANTS.md): per-width rows sum
    // exactly to the device totals on every modeled counter
    let sum = |f: fn(&apfp::coordinator::WidthModelSnapshot) -> u64| {
        by_width.iter().map(f).sum::<u64>()
    };
    assert_eq!(sum(|w| w.tiles), snap.tiles);
    assert_eq!(sum(|w| w.launches), snap.launches);
    assert_eq!(sum(|w| w.cycles), snap.cycles);
    assert_eq!(sum(|w| w.macs), snap.macs);
    assert_eq!(sum(|w| w.dram_bytes), snap.dram_bytes);
    assert_eq!(sum(|w| w.compute_ps), snap.compute_ps);
    assert_eq!(sum(|w| w.mem_ps), snap.mem_ps);
    assert_eq!(sum(|w| w.energy_pj), snap.energy_pj);
    // same geometry, wider words: more modeled energy and traffic per
    // tile (the whole reason the refinement loop mixes widths); raw
    // cycles can tie below the II knee, so pin the width-sensitive axes
    let per_tile = |w: &apfp::coordinator::WidthModelSnapshot| {
        (w.energy_pj / w.tiles, w.dram_bytes / w.tiles)
    };
    assert!(per_tile(&by_width[2]) > per_tile(&by_width[1]));
    assert!(per_tile(&by_width[1]) > per_tile(&by_width[0]));
}

#[test]
fn failed_launch_contributes_nothing_to_the_ledger() {
    // permanent failure + fail-fast: the launch errors, and even though
    // the other tiles of the launch computed successfully (and carried
    // model data home), retirement never happens — the ledger must stay
    // dead.  A follow-up healthy launch then matches a clean device.
    let faults = FaultSpec { fail_tile: Some((0, 4)), ..Default::default() };
    let cfg_faulted = ApfpConfig {
        backend: BackendKind::Sim,
        compute_units: 2,
        tile_n: 4,
        tile_m: 4,
        tile_k: 4,
        faults,
        retry: RetryPolicy { retry_limit: 0, backoff_ms: 0, ..Default::default() },
        ..Default::default()
    };
    let dir = std::env::temp_dir().join("apfp_sim_backend_no_artifacts/none");
    let dev = Device::new(cfg_faulted, &dir).expect("sim device");

    let a = Matrix::random(8, 8, 448, 700, 30);
    let b = Matrix::random(8, 8, 448, 701, 30);
    let c = Matrix::random(8, 8, 448, 702, 30);
    assert!(dev.gemm(&a, &b, &c).is_err(), "permanent tile fault must fail the launch");
    let m = dev.model_metrics();
    assert!(!m.is_live(), "failed launches accrue nothing: {m:?}");
    assert_eq!(m.launches, 0);
}
