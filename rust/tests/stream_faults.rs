//! Failure-path injection tests for the device stream (ISSUE 5 + the
//! ISSUE 7 self-healing ladder).
//!
//! Every fault — a backend error on a chosen tile, a worker panic, a CU
//! whose runtime never comes up, a handle used on the wrong stream, a wait
//! after an error — must surface as a **typed** [`StreamError`], never a
//! panic and never a hang, and the stream must stay usable afterwards
//! (a failed launch writes nothing, so C keeps its pre-launch contents).
//!
//! The healing ladder (ISSUE 7) is driven end to end here too: transient
//! tile faults retried to bit-identical success, a dead CU respawned and
//! its lost dispatches replayed, an exhausted respawn budget quarantining
//! the CU while the stream degrades onto the survivors, and the
//! zero-survivor bottom of the ladder poisoning with
//! [`StreamError::NoSurvivors`].
//!
//! Faults are injected through [`FaultSpec`] in the device config (the
//! crate's failpoints), so these tests drive the *real* worker/stream
//! machinery: the same reply channels, the same catch_unwind containment,
//! the same pool recycling.  Tile geometry is taken from the default
//! config so the CI tile-shape matrix (`APFP_TILE_N/M/K`) exercises the
//! fault paths under clipped and non-divisible tiles too.

use std::time::Duration;

use apfp::baseline;
use apfp::config::{ApfpConfig, FaultSpec, RetryPolicy};
use apfp::coordinator::scheduler::Partition;
use apfp::coordinator::{Device, Matrix, StreamError};
use apfp::runtime::BackendKind;

/// A builtin-manifest device with the given fault injection and retry
/// policy.  Honors `APFP_BACKEND` for native and sim (fault handling is
/// backend-agnostic and must be testable on any checkout, artifacts or
/// not — and under sim these tests additionally pin the model-ledger
/// conservation invariant across retries); xla cannot run artifact-less,
/// so it falls back to native.  The reply-probe interval is dropped to
/// 25ms so death detection is fast — these tests measure semantics, not
/// wall time.
fn healing_device(cus: usize, faults: FaultSpec, retry: RetryPolicy) -> Device {
    let backend = match BackendKind::from_env() {
        BackendKind::Xla => BackendKind::Native,
        b => b,
    };
    let cfg = ApfpConfig {
        backend,
        compute_units: cus,
        faults,
        retry,
        reply_timeout: Duration::from_millis(25),
        ..Default::default()
    };
    let dir = std::env::temp_dir().join("apfp_stream_faults_no_artifacts/none");
    Device::new(cfg, &dir).expect("builtin-manifest device must open on a clean checkout")
}

/// [`healing_device`] with the default retry budget and no backoff sleep.
fn faulty_device(cus: usize, faults: FaultSpec) -> Device {
    healing_device(cus, faults, RetryPolicy { backoff_ms: 0, ..Default::default() })
}

/// The (row, column) origin of a tile that exists in a `wide_m()`-column
/// output but not in a `tile_m`-column one — so one launch shape trips the
/// fault and another avoids it, whatever the configured tile geometry.
fn fault_origin() -> (usize, usize) {
    (0, 2 * ApfpConfig::default().tile_m)
}

/// Columns wide enough that the `fault_origin()` tile exists.
fn wide_m() -> usize {
    2 * ApfpConfig::default().tile_m + 1
}

fn launch_failed(err: &anyhow::Error) -> &StreamError {
    match err.downcast_ref::<StreamError>() {
        Some(se @ StreamError::LaunchFailed { .. }) => se,
        Some(other) => panic!("expected LaunchFailed, got {other:?}"),
        None => panic!("error must downcast to StreamError: {err:#}"),
    }
}

#[test]
fn injected_tile_error_is_typed_and_leaves_c_unchanged() {
    let (r0, c0) = fault_origin();
    let dev = faulty_device(2, FaultSpec { fail_tile: Some((r0, c0)), ..Default::default() });
    let (n, k, m) = (10, 6, wide_m());
    let a = Matrix::random(n, k, 448, 1, 30);
    let b = Matrix::random(k, m, 448, 2, 30);
    let c = Matrix::random(n, m, 448, 3, 30);

    let mut s = dev.stream().unwrap();
    let (ha, hb, hc) = (s.upload(&a), s.upload(&b), s.upload(&c));
    s.enqueue_gemm(ha, hb, hc).unwrap();
    let err = s.wait().expect_err("the injected tile failure must surface");
    match launch_failed(&err) {
        StreamError::LaunchFailed { failed, total, tiles, .. } => {
            assert_eq!(*failed, 1, "exactly the faulted tile fails: {tiles}");
            assert_eq!(*total, partition_for(&dev, n, m, k).total_tiles());
            assert!(tiles.contains(&format!("tile({r0},{c0})")), "{tiles}");
            assert!(tiles.contains("injected failure"), "{tiles}");
        }
        _ => unreachable!(),
    }
    // a failed launch writes nothing: C still holds its uploaded contents
    assert_eq!(s.download(hc).unwrap(), c, "failed launch must leave C unchanged");

    // the stream stays usable: a launch whose tiles avoid the faulted
    // origin runs to completion, bit-exact
    let m2 = ApfpConfig::default().tile_m.min(7);
    let b2 = Matrix::random(k, m2, 448, 4, 30);
    let c2 = Matrix::random(n, m2, 448, 5, 30);
    let (hb2, hc2) = (s.upload(&b2), s.upload(&c2));
    s.enqueue_gemm(ha, hb2, hc2).unwrap();
    s.wait().unwrap();
    assert_eq!(s.download(hc2).unwrap(), baseline::gemm_serial(&a, &b2, &c2));
}

#[test]
fn injected_tile_panic_is_caught_and_reported() {
    let (r0, c0) = fault_origin();
    let faults = FaultSpec { fail_tile: Some((r0, c0)), panic_tile: true, ..Default::default() };
    let dev = faulty_device(2, faults);
    let (n, k, m) = (9, 5, wide_m());
    let a = Matrix::random(n, k, 448, 10, 30);
    let b = Matrix::random(k, m, 448, 11, 30);
    let c = Matrix::random(n, m, 448, 12, 30);

    let mut s = dev.stream().unwrap();
    let (ha, hb, hc) = (s.upload(&a), s.upload(&b), s.upload(&c));
    s.enqueue_gemm(ha, hb, hc).unwrap();
    let err = s.wait().expect_err("a panicking tile must surface as an error, not a crash");
    match launch_failed(&err) {
        StreamError::LaunchFailed { failed, tiles, .. } => {
            assert_eq!(*failed, 1, "{tiles}");
            assert!(tiles.contains("panicked"), "panic must be named: {tiles}");
        }
        _ => unreachable!(),
    }
    assert_eq!(s.download(hc).unwrap(), c);
    // the worker survived the caught panic: the same stream still executes
    s.enqueue_gemm(ha, ha, ha).unwrap_err(); // shape mismatch is still typed...
    let sq = Matrix::random(k, k, 448, 13, 30);
    let hsq = s.upload(&sq);
    s.enqueue_gemm(hsq, hsq, hsq).unwrap();
    s.wait().unwrap();
    assert_eq!(s.download(hsq).unwrap(), baseline::gemm_serial(&sq, &sq, &sq));
}

fn partition_for(dev: &Device, n: usize, m: usize, k: usize) -> Partition {
    let t = dev.config().tile_shape();
    Partition {
        n,
        m,
        k,
        tile_n: t.n,
        tile_m: t.m,
        k_tile: t.k,
        compute_units: dev.config().compute_units,
    }
}

#[test]
fn cu_runtime_init_failure_errors_every_tile_of_its_band() {
    let dev = faulty_device(2, FaultSpec { init_fail_cu: Some(1), ..Default::default() });
    let (n, k, m) = (10, 6, wide_m());
    let a = Matrix::random(n, k, 448, 20, 30);
    let b = Matrix::random(k, m, 448, 21, 30);
    let c = Matrix::random(n, m, 448, 22, 30);
    let part = partition_for(&dev, n, m, k);
    let expected_failed = part.tiles_for(1).len();
    let expected_total = part.total_tiles();
    assert!(expected_failed >= 2, "test needs CU1 to own several tiles");

    let mut s = dev.stream().unwrap();
    let (ha, hb, hc) = (s.upload(&a), s.upload(&b), s.upload(&c));
    s.enqueue_gemm(ha, hb, hc).unwrap();
    let err = s.wait().expect_err("a dead CU's tiles must all error");
    match launch_failed(&err) {
        StreamError::LaunchFailed { failed, total, tiles, .. } => {
            // every failure is aggregated into the one error, not just the
            // first
            assert_eq!(*failed, expected_failed, "{tiles}");
            assert_eq!(*total, expected_total);
            assert_eq!(tiles.matches("slot1 tile(").count(), expected_failed, "{tiles}");
            assert!(tiles.contains("runtime unavailable"), "{tiles}");
        }
        _ => unreachable!(),
    }
    assert_eq!(s.download(hc).unwrap(), c, "no partial writeback from healthy CUs");

    // the stream-operator path over the same dead CU errors too (its
    // chunk replies an error) — and never hangs
    let x = Matrix::random(1, 64, 448, 23, 30);
    let y = Matrix::random(1, 64, 448, 24, 30);
    assert!(dev.mul_stream(x.row(0), y.row(0)).is_err());
}

#[test]
fn foreign_handles_are_rejected_across_streams_and_devices() {
    let dev1 = faulty_device(1, FaultSpec::default());
    let dev2 = faulty_device(1, FaultSpec::default());
    let a = Matrix::random(6, 6, 448, 30, 30);
    let mut s1 = dev1.stream().unwrap();
    let mut s2 = dev1.stream().unwrap(); // same device, different stream
    let mut s3 = dev2.stream().unwrap(); // different device entirely
    let h1 = s1.upload(&a);
    let h2 = s2.upload(&a);
    let h3 = s3.upload(&a);
    assert_ne!(h1, h2, "same index on different streams must not compare equal");

    for (err, what) in [
        (s2.enqueue_gemm(h1, h2, h2).expect_err("foreign A"), "enqueue A"),
        (s2.enqueue_gemm(h2, h1, h2).expect_err("foreign B"), "enqueue B"),
        (s2.enqueue_gemm(h2, h2, h1).expect_err("foreign C"), "enqueue C"),
        (s2.download(h1).expect_err("foreign download"), "download"),
        (s3.download(h1).expect_err("cross-device download"), "cross-device"),
        (s1.download(h3).expect_err("cross-device reverse"), "cross-device reverse"),
    ] {
        assert!(
            matches!(err.downcast_ref::<StreamError>(), Some(StreamError::ForeignHandle { .. })),
            "{what}: {err:#}"
        );
    }

    // rejection happened before any state change: all three streams work
    for (s, h) in [(&mut s1, h1), (&mut s2, h2), (&mut s3, h3)] {
        s.enqueue_gemm(h, h, h).unwrap();
        s.wait().unwrap();
        assert_eq!(s.download(h).unwrap(), baseline::gemm_serial(&a, &a, &a));
    }
}

#[test]
fn wait_after_error_sequences_stay_clean() {
    let (r0, c0) = fault_origin();
    let dev = faulty_device(2, FaultSpec { fail_tile: Some((r0, c0)), ..Default::default() });
    let (n, k) = (8, 5);
    let a = Matrix::random(n, k, 448, 40, 30);
    let bw = Matrix::random(k, wide_m(), 448, 41, 30);
    let cw = Matrix::random(n, wide_m(), 448, 42, 30);

    let mut s = dev.stream().unwrap();
    let (ha, hbw, hcw) = (s.upload(&a), s.upload(&bw), s.upload(&cw));

    // fail -> wait(Err) -> wait(Ok): the error drains everything, so a
    // second wait has nothing pending and reports clean
    s.enqueue_gemm(ha, hbw, hcw).unwrap();
    assert!(s.wait().is_err());
    s.wait().unwrap();

    // fail -> download(Err) -> download(Ok): download surfaces the launch
    // failure once, then reads the unchanged buffer
    s.enqueue_gemm(ha, hbw, hcw).unwrap();
    let err = s.download(hcw).expect_err("download must surface the drained failure");
    launch_failed(&err);
    assert_eq!(s.download(hcw).unwrap(), cw);
}

#[test]
fn zero_survivors_poison_the_stream_instead_of_hanging() {
    // The bottom of the healing ladder.  One CU that dies on *every*
    // delivery of one tile: the liveness probe detects the reply-less
    // death, the supervisor respawns it (default budget: once), the
    // replayed tile kills the fresh incarnation too, the second respawn
    // request quarantines the CU — and with zero survivors left the drain
    // must turn the loss into a typed NoSurvivors within a bounded time,
    // poison the stream, and every later call must report Poisoned — no
    // hang, no panic.
    let tm = ApfpConfig::default().tile_m;
    let tn = ApfpConfig::default().tile_n;
    // die on the launch's last tile so every job is already submitted and
    // the leader is blocked in wait() when the thread exits
    let die_at = (0, 2 * tm);
    let dev = faulty_device(1, FaultSpec { die_on_tile: Some(die_at), ..Default::default() });
    let (n, k, m) = (tn.min(8), 5, wide_m());
    let a = Matrix::random(n, k, 448, 60, 30);
    let b = Matrix::random(k, m, 448, 61, 30);
    let c = Matrix::random(n, m, 448, 62, 30);

    let mut s = dev.stream().unwrap();
    let (ha, hb, hc) = (s.upload(&a), s.upload(&b), s.upload(&c));
    s.enqueue_gemm(ha, hb, hc).unwrap();
    let t0 = std::time::Instant::now();
    let err = s.wait().expect_err("a reply-less dead worker must be detected");
    assert!(
        t0.elapsed() < std::time::Duration::from_secs(30),
        "liveness detection must be bounded, took {:?}",
        t0.elapsed()
    );
    assert!(
        matches!(err.downcast_ref::<StreamError>(), Some(StreamError::NoSurvivors { .. })),
        "{err:#}"
    );
    // the whole ladder ran: one respawn spent, then quarantine
    let m = dev.metrics();
    assert_eq!(m.respawns, 1, "the respawn budget was spent before quarantining");
    assert_eq!(m.quarantined_cus, 1, "the re-dead CU must be quarantined");
    assert!(m.retries >= 1, "the lost dispatch was replayed at least once");
    let health = dev.health();
    assert_eq!(health.len(), 1);
    assert_eq!(health[0].respawns, 1);
    assert!(health[0].quarantined, "health ledger must record the quarantine");
    assert!(health[0].last_incident.is_some(), "health ledger must record the incident");
    // the stream is cleanly poisoned: every later call reports it
    for attempt in 0..2 {
        let err = s.wait().expect_err("poisoned stream must keep erroring");
        assert!(
            matches!(err.downcast_ref::<StreamError>(), Some(StreamError::Poisoned { .. })),
            "attempt {attempt}: {err:#}"
        );
    }
    let err = s.enqueue_gemm(ha, hb, hc).expect_err("enqueue on a poisoned stream");
    assert!(
        matches!(err.downcast_ref::<StreamError>(), Some(StreamError::Poisoned { .. })),
        "{err:#}"
    );
    let err = s.download(hc).expect_err("download on a poisoned stream");
    assert!(
        matches!(err.downcast_ref::<StreamError>(), Some(StreamError::Poisoned { .. })),
        "{err:#}"
    );
    // a fresh stream on the same device hits the zero-survivor gate at
    // enqueue: the quarantine ledger is device-wide, not per stream
    let mut s2 = dev.stream().unwrap();
    let (ha2, hb2, hc2) = (s2.upload(&a), s2.upload(&b), s2.upload(&c));
    let err = s2.enqueue_gemm(ha2, hb2, hc2).expect_err("no CU survives to enqueue onto");
    assert!(
        matches!(err.downcast_ref::<StreamError>(), Some(StreamError::NoSurvivors { .. })),
        "{err:#}"
    );
}

#[test]
fn dependent_enqueue_surfaces_the_failed_launch_it_waits_on() {
    let (r0, c0) = fault_origin();
    let dev = faulty_device(2, FaultSpec { fail_tile: Some((r0, c0)), ..Default::default() });
    let (n, k, m) = (8, 5, wide_m());
    let a = Matrix::random(n, k, 448, 50, 30);
    let b = Matrix::random(k, m, 448, 51, 30);
    let c = Matrix::random(n, m, 448, 52, 30);
    let d = Matrix::random(m, 4, 448, 53, 30);
    let e = Matrix::random(n, 4, 448, 54, 30);

    let mut s = dev.stream().unwrap();
    let (ha, hb, hc) = (s.upload(&a), s.upload(&b), s.upload(&c));
    let (hd, he) = (s.upload(&d), s.upload(&e));
    s.enqueue_gemm(ha, hb, hc).unwrap(); // will fail at (r0, c0)
    // reads hc -> RAW hazard -> drains the failing launch and reports it
    let err = s.enqueue_gemm(hc, hd, he).expect_err("hazard drain must propagate the failure");
    launch_failed(&err);
    // the dependent launch was never submitted: nothing in flight, E and C
    // both untouched
    s.wait().unwrap();
    assert_eq!(s.download(he).unwrap(), e);
    assert_eq!(s.download(hc).unwrap(), c);
    // and the chain can be retried cleanly on a fault-free shape
    let m2 = 4;
    let b2 = Matrix::random(k, m2, 448, 55, 30);
    let c2 = Matrix::random(n, m2, 448, 56, 30);
    let (hb2, hc2) = (s.upload(&b2), s.upload(&c2));
    s.enqueue_gemm(ha, hb2, hc2).unwrap();
    let c2_next = baseline::gemm_serial(&a, &b2, &c2);
    assert_eq!(s.download(hc2).unwrap(), c2_next);
}

#[test]
fn transient_tile_fault_is_retried_to_bit_identical_success() {
    // First rung of the ladder: `fail_tile=RxC*2` fails the faulted
    // tile's first two deliveries, the third succeeds — inside the
    // default retry budget (retry_limit = 2 redispatches), so the launch
    // completes with no surfaced error and the result is bit-identical to
    // the serial reference.
    let (r0, c0) = fault_origin();
    let faults = FaultSpec {
        fail_tile: Some((r0, c0)),
        fail_attempts: Some(2),
        ..Default::default()
    };
    let dev = faulty_device(2, faults);
    let (n, k, m) = (10, 6, wide_m());
    let a = Matrix::random(n, k, 448, 80, 30);
    let b = Matrix::random(k, m, 448, 81, 30);
    let c = Matrix::random(n, m, 448, 82, 30);

    let mut s = dev.stream().unwrap();
    let (ha, hb, hc) = (s.upload(&a), s.upload(&b), s.upload(&c));
    s.enqueue_gemm(ha, hb, hc).unwrap();
    s.wait().expect("a transient fault inside the retry budget must heal");
    let once = baseline::gemm_serial(&a, &b, &c);
    assert_eq!(s.download(hc).unwrap(), once, "retried launch must stay bit-identical");
    let metrics = dev.metrics();
    assert_eq!(metrics.retries, 2, "exactly the two failed deliveries were retried");
    assert_eq!(metrics.respawns, 0, "an errored tile never costs a respawn");
    assert_eq!(metrics.quarantined_cus, 0);

    // a second, dependent launch trips the same transient fault (attempt
    // counts are per delivery, not global) and heals the same way: the
    // chain stays bit-exact across launches
    s.enqueue_gemm(ha, hb, hc).unwrap();
    s.wait().expect("the second launch must heal too");
    assert_eq!(s.download(hc).unwrap(), baseline::gemm_serial(&a, &b, &once));
    assert_eq!(dev.metrics().retries, 4);
}

#[test]
fn cu_death_is_respawned_and_inflight_launches_complete_bit_identical() {
    // Second rung: `die_on_tile=RxC*1` kills CU0's thread on the faulted
    // tile's first delivery only.  The liveness probe detects the
    // reply-less death, the supervisor respawns the CU with a fresh
    // runtime, and every lost dispatch — including the second, pipelined
    // launch's jobs that died in the old incarnation's queue — is
    // replayed.  Both launches must complete bit-identical to the serial
    // reference.
    let tn = ApfpConfig::default().tile_n;
    let die_at = fault_origin(); // row 0: CU0's band; absent from narrow shapes
    let faults = FaultSpec {
        die_on_tile: Some(die_at),
        die_attempts: Some(1),
        ..Default::default()
    };
    let dev = faulty_device(2, faults);
    let (n, k) = (2 * tn, 5); // two non-empty bands
    let a = Matrix::random(n, k, 448, 90, 30);
    let b = Matrix::random(k, wide_m(), 448, 91, 30);
    let c = Matrix::random(n, wide_m(), 448, 92, 30);
    // an independent launch with a die-origin-free shape, pipelined behind
    // the dying one over disjoint buffers
    let m2 = ApfpConfig::default().tile_m.min(7);
    let a2 = Matrix::random(n, k, 448, 93, 30);
    let b2 = Matrix::random(k, m2, 448, 94, 30);
    let c2 = Matrix::random(n, m2, 448, 95, 30);

    let mut s = dev.stream().unwrap();
    let (ha, hb, hc) = (s.upload(&a), s.upload(&b), s.upload(&c));
    let (ha2, hb2, hc2) = (s.upload(&a2), s.upload(&b2), s.upload(&c2));
    s.enqueue_gemm(ha, hb, hc).unwrap();
    s.enqueue_gemm(ha2, hb2, hc2).unwrap();
    s.wait().expect("a single CU death must heal through respawn");

    assert_eq!(s.download(hc).unwrap(), baseline::gemm_serial(&a, &b, &c));
    assert_eq!(s.download(hc2).unwrap(), baseline::gemm_serial(&a2, &b2, &c2));
    let metrics = dev.metrics();
    assert!(metrics.inflight_max >= 2, "disjoint launches must pipeline: {metrics:?}");
    assert_eq!(metrics.respawns, 1, "one death, one respawn");
    assert_eq!(metrics.quarantined_cus, 0, "the respawn budget absorbed the death");
    assert!(metrics.retries >= 1, "the lost dispatch was replayed: {metrics:?}");
    let health = dev.health();
    assert_eq!(health[0].respawns, 1, "health ledger must record CU0's respawn");
    assert!(!health[0].quarantined);
    assert!(health[0].last_incident.is_some(), "the incident must be on the ledger");
    assert_eq!(health[1].respawns, 0, "CU1 never died");
}

#[test]
fn exhausted_respawn_budget_quarantines_and_the_stream_degrades() {
    // Third rung: with `respawn_limit = 0` the first death quarantines
    // CU0 outright.  Its lost dispatch re-routes to the survivor and the
    // in-flight launch still completes bit-identical; a later launch on
    // the same stream schedules degraded (banded across the survivors
    // only) from the start — quarantine is scheduling state, not poison.
    let tn = ApfpConfig::default().tile_n;
    let die_at = fault_origin();
    let faults = FaultSpec {
        die_on_tile: Some(die_at),
        die_attempts: Some(1),
        ..Default::default()
    };
    let retry = RetryPolicy { respawn_limit: 0, backoff_ms: 0, ..Default::default() };
    let dev = healing_device(2, faults, retry);
    let (n, k) = (2 * tn, 5);
    let a = Matrix::random(n, k, 448, 100, 30);
    let b = Matrix::random(k, wide_m(), 448, 101, 30);
    let c = Matrix::random(n, wide_m(), 448, 102, 30);

    let mut s = dev.stream().unwrap();
    let (ha, hb, hc) = (s.upload(&a), s.upload(&b), s.upload(&c));
    s.enqueue_gemm(ha, hb, hc).unwrap();
    s.wait().expect("the lost dispatch must re-route to the survivor");
    assert_eq!(s.download(hc).unwrap(), baseline::gemm_serial(&a, &b, &c));
    let metrics = dev.metrics();
    assert_eq!(metrics.respawns, 0, "a zero respawn budget quarantines without respawning");
    assert_eq!(metrics.quarantined_cus, 1, "{metrics:?}");
    let health = dev.health();
    assert!(health[0].quarantined, "CU0 must be quarantined on the ledger");
    assert_eq!(health[0].respawns, 0);
    assert!(!health[1].quarantined, "the survivor stays in service");

    // degraded-mode scheduling: a fresh launch with a die-origin-free
    // shape runs entirely on the survivor, bit-identical, with no new
    // incidents
    let m2 = ApfpConfig::default().tile_m.min(7);
    let b2 = Matrix::random(k, m2, 448, 103, 30);
    let c2 = Matrix::random(n, m2, 448, 104, 30);
    let (hb2, hc2) = (s.upload(&b2), s.upload(&c2));
    s.enqueue_gemm(ha, hb2, hc2).unwrap();
    s.wait().expect("a degraded stream must stay usable");
    assert_eq!(s.download(hc2).unwrap(), baseline::gemm_serial(&a, &b2, &c2));
    assert_eq!(dev.metrics().quarantined_cus, 1, "no new quarantines in degraded mode");
}
