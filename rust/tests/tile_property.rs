//! Property test for the config-driven tile geometry: randomized
//! `tile_n`/`tile_m`/`tile_k` (including non-divisible edge shapes, tiles
//! larger than the matrix, and single-row/column degenerates) driven
//! through the scheduler and the native (or sim — the geometry is
//! backend-agnostic and `APFP_BACKEND=sim` runs the same suite) backend
//! must stay bit-identical to `baseline::gemm_serial` — the same
//! acceptance criterion the paper applies to its FPGA against MPFR, here
//! applied to every legal tiling.
//!
//! On `APFP_BACKEND=xla` without artifacts these tests skip (the builtin
//! manifest whose geometry is under test needs no artifact files).

use apfp::baseline;
use apfp::config::ApfpConfig;
use apfp::coordinator::{Device, Matrix};
use apfp::runtime::BackendKind;
use apfp::testkit::Rng;

fn builtin_device(cfg: ApfpConfig) -> Option<Device> {
    // A guaranteed-absent artifact dir: the property is about the *builtin*
    // manifest's geometry, so an on-disk artifacts/manifest.txt (whose
    // compiled geometry deliberately wins over the config) must not leak in.
    let dir = std::env::temp_dir().join("apfp_tile_property_no_artifacts/none");
    if !matches!(cfg.backend, BackendKind::Native | BackendKind::Sim) {
        eprintln!("skipped: tile-geometry property is a builtin-manifest feature");
        return None;
    }
    Some(Device::new(cfg, &dir).expect("builtin-manifest device must open on a clean checkout"))
}

#[test]
fn randomized_tile_shapes_stay_bit_exact() {
    let mut rng = Rng::from_seed(0x7112E);
    for case in 0..18u64 {
        let tile_n = rng.range_i64(1, 9) as usize;
        let tile_m = rng.range_i64(1, 9) as usize;
        let tile_k = rng.range_i64(1, 9) as usize;
        let cus = rng.range_i64(1, 3) as usize;
        let n = rng.range_i64(1, 19) as usize;
        let k = rng.range_i64(1, 14) as usize;
        let m = rng.range_i64(1, 19) as usize;
        let cfg = ApfpConfig { compute_units: cus, tile_n, tile_m, tile_k, ..Default::default() };
        let Some(dev) = builtin_device(cfg) else { return };

        let a = Matrix::random(n, k, 448, 1000 + case, 40);
        let b = Matrix::random(k, m, 448, 2000 + case, 40);
        let c = Matrix::random(n, m, 448, 3000 + case, 40);
        let (got, stats) = dev.gemm(&a, &b, &c).unwrap();
        let want = baseline::gemm_serial(&a, &b, &c);
        assert_eq!(
            got, want,
            "case {case}: {n}x{k}x{m} on {cus} CUs with {tile_n}x{tile_m}x{tile_k} tiles"
        );
        assert!(stats.tiles > 0 && stats.artifact_calls >= stats.tiles);
    }
}

#[test]
fn randomized_tiles_through_a_chained_stream() {
    // The same property through the batched API, now mixing every launch
    // relationship the hazard tracker distinguishes: a dependent chain
    // (E += C@D reads the C a previous launch wrote), an independent
    // launch with a disjoint write set (F += A@B, pipelined alongside),
    // and an aliased self-chain (E += E@Bsq, read and write sets meet) —
    // all across random tile geometry, against serial baseline
    // applications in enqueue order.
    let mut rng = Rng::from_seed(0x57BEA);
    for case in 0..8u64 {
        let tile_n = rng.range_i64(1, 7) as usize;
        let tile_m = rng.range_i64(1, 7) as usize;
        let tile_k = rng.range_i64(1, 7) as usize;
        let cus = rng.range_i64(1, 3) as usize;
        let n = rng.range_i64(1, 13) as usize;
        let k = rng.range_i64(1, 10) as usize;
        let m = rng.range_i64(1, 13) as usize;
        let p = rng.range_i64(1, 10) as usize;
        let cfg = ApfpConfig { compute_units: cus, tile_n, tile_m, tile_k, ..Default::default() };
        let Some(dev) = builtin_device(cfg) else { return };

        let a = Matrix::random(n, k, 448, 4000 + case, 30);
        let b = Matrix::random(k, m, 448, 5000 + case, 30);
        let c = Matrix::random(n, m, 448, 6000 + case, 30);
        let d = Matrix::random(m, p, 448, 7000 + case, 30);
        let e = Matrix::random(n, p, 448, 8000 + case, 30);
        let f = Matrix::random(n, m, 448, 9000 + case, 30);
        let bsq = Matrix::random(p, p, 448, 9500 + case, 30);

        let mut s = dev.stream().unwrap();
        let (ha, hb, hc) = (s.upload(&a), s.upload(&b), s.upload(&c));
        let (hd, he) = (s.upload(&d), s.upload(&e));
        let (hf, hbsq) = (s.upload(&f), s.upload(&bsq));
        s.enqueue_gemm(ha, hb, hc).unwrap(); // C += A@B
        s.enqueue_gemm(hc, hd, he).unwrap(); // dependent: reads updated C
        s.enqueue_gemm(ha, hb, hf).unwrap(); // independent: disjoint write
        s.enqueue_gemm(he, hbsq, he).unwrap(); // aliased self-chain on E

        let c1 = baseline::gemm_serial(&a, &b, &c);
        let e1 = baseline::gemm_serial(&c1, &d, &e);
        let e2 = baseline::gemm_serial(&e1, &bsq, &e1);
        let f1 = baseline::gemm_serial(&a, &b, &f);
        let shapes = format!(
            "case {case}: {n}x{k}x{m}x{p} on {cus} CUs with {tile_n}x{tile_m}x{tile_k} tiles"
        );
        assert_eq!(s.download(he).unwrap(), e2, "{shapes}");
        assert_eq!(s.download(hc).unwrap(), c1, "{shapes}");
        assert_eq!(s.download(hf).unwrap(), f1, "{shapes}");
    }
}
