//! The apfp-lint rule engine.
//!
//! This is a deliberate line-mirror of the executable specification in
//! `python/tests/apfp_lint.py` — both implementations are regex-free
//! scanners over masked source text, pinned against each other by the
//! shared fixtures in `tests/fixtures/` (the same dual-implementation
//! strategy PRs 1–5 used for the numeric kernels).  When changing a rule,
//! change both engines and extend a fixture that proves the behavior.
//!
//! Three rule families (docs/INVARIANTS.md is the catalogue):
//!
//! * `alloc` / `alloc-coverage` — functions annotated `// apfp-lint:
//!   no_alloc` are transitively checked against an allocation denylist,
//!   and every annotated function must be exercised (by name) by
//!   `tests/alloc_free.rs` or be reachable from one that is.
//! * `panic` / `index` — no `unwrap`/`expect`/`panic!`-family macros and
//!   no unguarded slice subscripts in `runtime/` (the simulated backend's
//!   model accounting in `runtime/sim_backend.rs` included — the
//!   `panic_bad` fixture pins that path), `coordinator/` (where the sim
//!   ledger `coordinator/model_metrics.rs` lives) and `config.rs`
//!   outside `#[cfg(test)]`.
//! * `hazard` — mechanical protocol shape of `coordinator/stream.rs` /
//!   `worker.rs`: every `TileResult` literal carries `c_buf`, reply
//!   receives are `recv_timeout`, and no unbounded/shared
//!   `Inflight`-style channel reappears.
//!
//! Escape hatch, shared grammar with the Python port:
//!
//! ```text
//! // apfp-lint: allow(<rule>, reason="why this site is fine")
//! // apfp-lint: allow(<rule>, scope=fn, reason="why this whole fn is fine")
//! // apfp-lint: no_alloc
//! ```
//!
//! A trailing same-line `allow` applies to that line; a standalone comment
//! line applies to the next line of code; `scope=fn` (and `no_alloc`)
//! attach to the next `fn` item.  A `scope=fn` alloc allow also stops the
//! transitive walk at that function (it is a declared cold path).

use std::collections::{BTreeMap, BTreeSet, HashSet};
use std::fmt::Write as _;
use std::path::Path;

pub const RULE_ALLOC: &str = "alloc";
pub const RULE_COVERAGE: &str = "alloc-coverage";
pub const RULE_PANIC: &str = "panic";
pub const RULE_INDEX: &str = "index";
pub const RULE_HAZARD: &str = "hazard";
pub const RULE_ANNOTATION: &str = "annotation";

pub const KNOWN_RULES: [&str; 5] =
    [RULE_ALLOC, RULE_COVERAGE, RULE_PANIC, RULE_INDEX, RULE_HAZARD];

/// Kernel roots that must carry `// apfp-lint: no_alloc` at every non-test
/// definition: the fixed-width GEMM fast path is only sound while its
/// entry points stay on the allocation-free discipline, so silently
/// dropping an annotation (and with it the transitive denylist walk) is
/// itself an `alloc-coverage` finding.
pub const REQUIRED_NO_ALLOC: [&str; 3] = ["mul_fixed", "gemm_fixed", "exec_gemm_tile_fixed"];

/// Files subject to the panic / index discipline (relative-path prefixes).
const PANIC_SCOPE: [&str; 3] = ["runtime/", "coordinator/", "config.rs"];
/// Files subject to the hazard-protocol structure rule.
const HAZARD_SCOPE: [&str; 2] = ["coordinator/stream.rs", "coordinator/worker.rs"];

/// Allocation denylist: (needle, label).  Needles starting with an
/// identifier character additionally require a non-identifier character
/// before the match.
const DENY_ALLOC: [(&str, &str); 20] = [
    ("vec!", "vec! macro"),
    ("format!", "format! macro"),
    ("Vec::new", "Vec::new"),
    ("Vec::with_capacity", "Vec::with_capacity"),
    ("Vec::from", "Vec::from"),
    ("Box::new", "Box::new"),
    ("String::new", "String::new"),
    ("String::from", "String::from"),
    ("String::with_capacity", "String::with_capacity"),
    ("sync_channel(", "sync_channel"),
    (".to_vec(", "to_vec"),
    (".to_string(", "to_string"),
    (".to_owned(", "to_owned"),
    (".clone(", "clone"),
    (".collect(", "collect"),
    (".collect::<", "collect"),
    (".with_capacity(", "with_capacity"),
    (".resize(", "resize"),
    (".resize_with(", "resize_with"),
    (".reserve(", "reserve"),
];

/// Panic-family denylist for the panic rule.
const DENY_PANIC: [(&str, &str); 6] = [
    (".unwrap(", "unwrap"),
    (".expect(", "expect"),
    ("panic!", "panic! macro"),
    ("unreachable!", "unreachable! macro"),
    ("todo!", "todo! macro"),
    ("unimplemented!", "unimplemented! macro"),
];

/// A subscript identifier counts as guarded when some earlier line of the
/// same fn mentions it together with one of these markers (loop bounds,
/// asserts, modulo arithmetic, clamping).
const GUARD_MARKS: [&str; 13] = [
    "for ", "while ", "if ", "assert", "ensure!", "%", ".min(", ".max(",
    "match ", "clamp(", " < ", " <= ", "..",
];

/// Identifiers never treated as unguarded subscript variables.
const INDEX_IDENT_SKIP: [&str; 14] = [
    "self", "as", "usize", "u8", "u16", "u32", "u64", "i8", "i16", "i32",
    "i64", "f32", "f64", "len",
];

fn is_ident(ch: u8) -> bool {
    ch.is_ascii_alphanumeric() || ch == b'_'
}

#[derive(Clone, Debug)]
pub struct Finding {
    pub rule: &'static str,
    pub file: String,
    pub line: usize,
    pub message: String,
    pub allowed: bool,
    pub reason: Option<String>,
}

impl Finding {
    fn deny(rule: &'static str, file: &str, line: usize, message: String) -> Self {
        Finding { rule, file: file.to_string(), line, message, allowed: false, reason: None }
    }

    fn key(&self) -> (String, usize, &'static str, String) {
        (self.file.clone(), self.line, self.rule, self.message.clone())
    }
}

#[derive(Clone, Debug)]
struct Ann {
    kind: AnnKind,
    line: usize, // 1-based line the comment sits on
    rule: &'static str,
    reason: String,
    scope_fn: bool,
}

#[derive(Clone, Copy, Debug, PartialEq)]
enum AnnKind {
    NoAlloc,
    Allow,
}

#[derive(Clone, Debug)]
struct FnRec {
    name: String,
    file: String,
    sig_line: usize,
    body_start_line: usize,
    end_line: usize,
    body: Vec<u8>, // masked body text including braces
    no_alloc: bool,
    no_alloc_line: usize,
    cold: bool, // carries a scope=fn alloc allow: walk stops here
    fn_allows: Vec<(&'static str, String)>,
    callees: BTreeSet<String>,
}

struct FileLint {
    rel: String,
    masked: Vec<u8>,
    line_starts: Vec<usize>,
    lines: Vec<String>,
    masked_lines: Vec<String>,
    site_allows: BTreeMap<usize, Vec<(&'static str, String)>>,
    fns: Vec<FnRec>,
    test_ranges: Vec<(usize, usize)>,
}

impl FileLint {
    fn line_of(&self, off: usize) -> usize {
        self.line_starts.partition_point(|&s| s <= off)
    }

    fn in_test(&self, line: usize) -> bool {
        self.test_ranges.iter().any(|&(a, b)| a <= line && line <= b)
    }

    fn enclosing_fns(&self, line: usize) -> Vec<usize> {
        (0..self.fns.len())
            .filter(|&i| self.fns[i].sig_line <= line && line <= self.fns[i].end_line)
            .collect()
    }
}

/// Blank out comments, string/char literals (newlines preserved).
fn mask_source(src: &[u8]) -> Vec<u8> {
    let mut out = src.to_vec();
    let n = src.len();
    let blank = |out: &mut Vec<u8>, a: usize, b: usize| {
        for slot in out.iter_mut().take(b.min(n)).skip(a) {
            if *slot != b'\n' {
                *slot = b' ';
            }
        }
    };
    let starts_with = |at: usize, pat: &[u8]| src[at..].starts_with(pat);

    let mut i = 0;
    while i < n {
        let c = src[i];
        if c == b'/' && starts_with(i, b"//") {
            let j = memfind(src, b"\n", i).unwrap_or(n);
            blank(&mut out, i, j);
            i = j;
        } else if c == b'/' && starts_with(i, b"/*") {
            let mut depth = 1usize;
            let mut j = i + 2;
            while j < n && depth > 0 {
                if starts_with(j, b"/*") {
                    depth += 1;
                    j += 2;
                } else if starts_with(j, b"*/") {
                    depth -= 1;
                    j += 2;
                } else {
                    j += 1;
                }
            }
            blank(&mut out, i, j);
            i = j;
        } else if c == b'r' && (i == 0 || !is_ident(src[i - 1])) {
            // raw string r"..." / r#"..."#
            let mut j = i + 1;
            let mut hashes = 0usize;
            while j < n && src[j] == b'#' {
                hashes += 1;
                j += 1;
            }
            if j < n && src[j] == b'"' {
                let mut close = vec![b'"'];
                close.extend(std::iter::repeat(b'#').take(hashes));
                let k = match memfind(src, &close, j + 1) {
                    Some(k) => k + close.len(),
                    None => n,
                };
                blank(&mut out, i, k);
                i = k;
            } else {
                i += 1;
            }
        } else if c == b'"' {
            let mut j = i + 1;
            while j < n {
                if src[j] == b'\\' {
                    j += 2;
                } else if src[j] == b'"' {
                    j += 1;
                    break;
                } else {
                    j += 1;
                }
            }
            blank(&mut out, i, j);
            i = j;
        } else if c == b'\'' {
            if i + 1 < n && src[i + 1] == b'\\' {
                let mut j = i + 2;
                while j < n && src[j] != b'\'' {
                    j += 1;
                }
                blank(&mut out, i, j + 1);
                i = j + 1;
            } else if i + 2 < n && src[i + 2] == b'\'' {
                blank(&mut out, i, i + 3);
                i += 3;
            } else {
                i += 1; // lifetime
            }
        } else {
            i += 1;
        }
    }
    out
}

fn memfind(hay: &[u8], needle: &[u8], from: usize) -> Option<usize> {
    if needle.is_empty() || from > hay.len() {
        return None;
    }
    hay[from..]
        .windows(needle.len())
        .position(|w| w == needle)
        .map(|p| p + from)
}

/// Offsets of `needle` in `line`; identifier-leading needles require a
/// non-identifier character immediately before the match.
fn find_with_boundary(line: &str, needle: &str) -> Vec<usize> {
    let bytes = line.as_bytes();
    let nb = needle.as_bytes();
    let mut hits = Vec::new();
    let mut start = 0;
    while let Some(k) = memfind(bytes, nb, start) {
        let ok = !(is_ident(nb[0]) && k > 0 && is_ident(bytes[k - 1]));
        if ok {
            hits.push(k);
        }
        start = k + 1;
    }
    hits
}

/// True when `ident` appears in `line` as a whole identifier.
fn ident_mentioned(line: &str, ident: &str) -> bool {
    let bytes = line.as_bytes();
    let ib = ident.as_bytes();
    let mut start = 0;
    while let Some(k) = memfind(bytes, ib, start) {
        let before_ok = k == 0 || !is_ident(bytes[k - 1]);
        let after = k + ib.len();
        let after_ok = after >= bytes.len() || !is_ident(bytes[after]);
        if before_ok && after_ok {
            return true;
        }
        start = k + 1;
    }
    false
}

/// Extract `// apfp-lint:` directives from original source lines.
fn parse_annotations(lines: &[String], findings: &mut Vec<Finding>, rel: &str) -> Vec<Ann> {
    let mut anns = Vec::new();
    for (idx, line) in lines.iter().enumerate() {
        let lineno = idx + 1;
        let Some(slash) = line.find("//") else { continue };
        let mut mark = line[slash..].find("apfp-lint:").map(|m| m + slash);
        while let Some(m) = mark {
            let nxt = line[m + 1..].find("apfp-lint:").map(|x| x + m + 1);
            let end = nxt.unwrap_or(line.len());
            parse_directive(line[m + "apfp-lint:".len()..end].trim(), lineno, &mut anns, findings, rel);
            mark = nxt;
        }
    }
    anns
}

fn parse_directive(
    body: &str,
    lineno: usize,
    anns: &mut Vec<Ann>,
    findings: &mut Vec<Finding>,
    rel: &str,
) {
    if body.starts_with("no_alloc") {
        anns.push(Ann {
            kind: AnnKind::NoAlloc,
            line: lineno,
            rule: RULE_ALLOC,
            reason: String::new(),
            scope_fn: false,
        });
        return;
    }
    if !body.starts_with("allow(") {
        let head: String = body.chars().take(40).collect();
        findings.push(Finding::deny(
            RULE_ANNOTATION, rel, lineno,
            format!("unrecognized apfp-lint directive `{head}`"),
        ));
        return;
    }
    let Some(close) = body.rfind(')') else {
        findings.push(Finding::deny(
            RULE_ANNOTATION, rel, lineno,
            "malformed apfp-lint allow: missing `)`".to_string(),
        ));
        return;
    };
    let inner = &body["allow(".len()..close];
    let mut reason: Option<&str> = None;
    let mut head = inner;
    if let Some(rq) = inner.find("reason=\"") {
        let after = rq + "reason=\"".len();
        let Some(rend) = inner[after..].find('"').map(|x| x + after) else {
            findings.push(Finding::deny(
                RULE_ANNOTATION, rel, lineno,
                "malformed apfp-lint reason: unterminated string".to_string(),
            ));
            return;
        };
        reason = Some(&inner[after..rend]);
        head = &inner[..rq];
    }
    let rule_name = head.split(',').next().unwrap_or("").trim();
    let scope_fn = head.contains("scope=fn");
    let Some(rule) = KNOWN_RULES.iter().find(|r| **r == rule_name).copied() else {
        findings.push(Finding::deny(
            RULE_ANNOTATION, rel, lineno,
            format!("unknown apfp-lint rule `{rule_name}`"),
        ));
        return;
    };
    let Some(reason) = reason.filter(|r| !r.trim().is_empty()) else {
        findings.push(Finding::deny(
            RULE_ANNOTATION, rel, lineno,
            format!("apfp-lint allow({rule}) needs a reason=\"...\""),
        ));
        return;
    };
    anns.push(Ann {
        kind: AnnKind::Allow,
        line: lineno,
        rule,
        reason: reason.to_string(),
        scope_fn,
    });
}

fn parse_fns(fl: &mut FileLint) {
    let masked = fl.masked.clone();
    let n = masked.len();
    let mut i = 0;
    while let Some(at) = memfind(&masked, b"fn", i) {
        i = at;
        let before = if i > 0 { masked[i - 1] } else { b' ' };
        let after = if i + 2 < n { masked[i + 2] } else { b' ' };
        if is_ident(before) || !after.is_ascii_whitespace() {
            i += 2;
            continue;
        }
        let mut j = i + 2;
        while j < n && masked[j].is_ascii_whitespace() {
            j += 1;
        }
        let name_start = j;
        while j < n && is_ident(masked[j]) {
            j += 1;
        }
        let name = String::from_utf8_lossy(&masked[name_start..j]).into_owned();
        if name.is_empty() {
            i += 2;
            continue;
        }
        // find the body-opening brace (skip the parameter list; `;` at
        // paren-depth 0 means a bodyless trait signature)
        let mut par = 0i32;
        let mut k = j;
        let mut body_start = None;
        while k < n {
            match masked[k] {
                b'(' => par += 1,
                b')' => par -= 1,
                b'{' if par == 0 => {
                    body_start = Some(k);
                    break;
                }
                b';' if par == 0 => break,
                _ => {}
            }
            k += 1;
        }
        let Some(body_start) = body_start else {
            i = if k > i { k } else { i + 2 };
            continue;
        };
        let mut depth = 0i32;
        let mut e = body_start;
        while e < n {
            if masked[e] == b'{' {
                depth += 1;
            } else if masked[e] == b'}' {
                depth -= 1;
                if depth == 0 {
                    e += 1;
                    break;
                }
            }
            e += 1;
        }
        fl.fns.push(FnRec {
            name,
            file: fl.rel.clone(),
            sig_line: fl.line_of(i),
            body_start_line: fl.line_of(body_start),
            end_line: fl.line_of(e.saturating_sub(1)),
            body: masked[body_start..e].to_vec(),
            no_alloc: false,
            no_alloc_line: 0,
            cold: false,
            fn_allows: Vec::new(),
            callees: BTreeSet::new(),
        });
        i = j;
    }
}

fn parse_test_ranges(fl: &mut FileLint) {
    let masked = fl.masked.clone();
    let n = masked.len();
    let mut i = 0;
    while let Some(at) = memfind(&masked, b"#[cfg(test)]", i) {
        let start_line = fl.line_of(at);
        let Some(k) = memfind(&masked, b"{", at) else {
            fl.test_ranges.push((start_line, fl.line_of(n.saturating_sub(1))));
            return;
        };
        let mut depth = 0i32;
        let mut e = k;
        while e < n {
            if masked[e] == b'{' {
                depth += 1;
            } else if masked[e] == b'}' {
                depth -= 1;
                if depth == 0 {
                    break;
                }
            }
            e += 1;
        }
        fl.test_ranges.push((start_line, fl.line_of(e.min(n.saturating_sub(1)))));
        i = e;
    }
}

/// Bind parsed directives to lines / fns; dangling ones are findings.
fn attach_annotations(fl: &mut FileLint, anns: &[Ann], findings: &mut Vec<Finding>) {
    for ann in anns {
        if ann.kind == AnnKind::Allow && !ann.scope_fn {
            let mut target = ann.line;
            let code = fl
                .masked_lines
                .get(ann.line - 1)
                .map(|l| l.trim().to_string())
                .unwrap_or_default();
            if code.is_empty() {
                // standalone comment: applies to the next line holding code
                target = 0;
                for idx in ann.line..fl.masked_lines.len() {
                    if !fl.masked_lines[idx].trim().is_empty() {
                        target = idx + 1;
                        break;
                    }
                }
                if target == 0 {
                    findings.push(Finding::deny(
                        RULE_ANNOTATION, &fl.rel, ann.line,
                        "dangling apfp-lint allow: no code follows".to_string(),
                    ));
                    continue;
                }
            }
            fl.site_allows.entry(target).or_default().push((ann.rule, ann.reason.clone()));
            continue;
        }
        // fn-scoped: nearest fn declared at or after the annotation line
        let mut target_fn: Option<usize> = None;
        for (idx, f) in fl.fns.iter().enumerate() {
            if f.sig_line >= ann.line
                && target_fn.map_or(true, |t| f.sig_line < fl.fns[t].sig_line)
            {
                target_fn = Some(idx);
            }
        }
        let Some(idx) = target_fn else {
            let kind = if ann.kind == AnnKind::NoAlloc { "no_alloc" } else { "allow" };
            findings.push(Finding::deny(
                RULE_ANNOTATION, &fl.rel, ann.line,
                format!("dangling apfp-lint {kind}: no fn follows"),
            ));
            continue;
        };
        if ann.kind == AnnKind::NoAlloc {
            fl.fns[idx].no_alloc = true;
            fl.fns[idx].no_alloc_line = ann.line;
        } else {
            fl.fns[idx].fn_allows.push((ann.rule, ann.reason.clone()));
            if ann.rule == RULE_ALLOC {
                fl.fns[idx].cold = true;
            }
        }
    }
}

fn parse_callees(f: &mut FnRec) {
    let body = &f.body;
    let n = body.len();
    let mut i = 0;
    while i < n {
        if is_ident(body[i])
            && !body[i].is_ascii_digit()
            && (i == 0 || !is_ident(body[i - 1]))
        {
            let mut j = i;
            while j < n && is_ident(body[j]) {
                j += 1;
            }
            let name = String::from_utf8_lossy(&body[i..j]).into_owned();
            let mut k = j;
            while k < n && body[k].is_ascii_whitespace() {
                k += 1;
            }
            let keyword = matches!(name.as_str(), "if" | "while" | "for" | "match" | "return" | "fn");
            if k < n && body[k] == b'(' && !keyword {
                f.callees.insert(name);
            }
            i = j;
        } else {
            i += 1;
        }
    }
}

/// (allowed, reason) for a finding at `line` of rule `rule`.
fn allow_for(fl: &FileLint, line: usize, rule: &'static str) -> (bool, Option<String>) {
    if let Some(allows) = fl.site_allows.get(&line) {
        for (r, reason) in allows {
            if *r == rule {
                return (true, Some(reason.clone()));
            }
        }
    }
    for idx in fl.enclosing_fns(line) {
        for (r, reason) in &fl.fns[idx].fn_allows {
            if *r == rule {
                return (true, Some(reason.clone()));
            }
        }
    }
    (false, None)
}

/// Flag denylist needles on lines [first, last] outside tests.
fn scan_denylist(
    fl: &FileLint,
    first: usize,
    last: usize,
    deny: &[(&str, &str)],
    rule: &'static str,
    findings: &mut Vec<Finding>,
    context: &str,
) {
    let mut seen: HashSet<(usize, String)> = HashSet::new();
    for lineno in first..=last {
        if lineno - 1 >= fl.masked_lines.len() || fl.in_test(lineno) {
            continue;
        }
        let line = &fl.masked_lines[lineno - 1];
        for (needle, label) in deny {
            if find_with_boundary(line, needle).is_empty() {
                continue;
            }
            if !seen.insert((lineno, label.to_string())) {
                continue;
            }
            let (allowed, reason) = allow_for(fl, lineno, rule);
            findings.push(Finding {
                rule,
                file: fl.rel.clone(),
                line: lineno,
                message: format!("`{label}`{context}"),
                allowed,
                reason,
            });
        }
    }
}

// ---------------------------------------------------------------------------
// Rule: alloc (+ coverage)
// ---------------------------------------------------------------------------

/// A function's identity in the cross-file call graph.
type FnKey = (String, usize, String);

fn fn_key(f: &FnRec) -> FnKey {
    (f.file.clone(), f.sig_line, f.name.clone())
}

/// Resolve `f`'s callee names to function keys.
///
/// Name-based resolution is deliberately conservative: a name is followed
/// only when it resolves unambiguously — definitions in the caller's own
/// file win; otherwise the name must have exactly one non-test definition
/// in the whole tree.  Ambiguous names (trait methods with several
/// implementations, ubiquitous names like `new`) are NOT traversed; each
/// trait-dispatched kernel carries its own `no_alloc` annotation instead,
/// so it is still checked as a root of its own.
fn resolve_callees(f: &FnRec, fn_map: &BTreeMap<String, Vec<FnKey>>) -> Vec<FnKey> {
    let mut out = Vec::new();
    for name in &f.callees {
        let Some(cands) = fn_map.get(name) else { continue };
        let same_file: Vec<&FnKey> = cands.iter().filter(|c| c.0 == f.file).collect();
        if !same_file.is_empty() {
            out.extend(same_file.into_iter().cloned());
        } else if cands.len() == 1 {
            out.push(cands[0].clone());
        }
    }
    out
}

fn run_alloc_rule(
    files: &BTreeMap<String, FileLint>,
    coverage_text: Option<&str>,
    findings: &mut Vec<Finding>,
) {
    // callee parsing needs &mut; collect fn records into an owned table
    let mut fn_table: BTreeMap<FnKey, FnRec> = BTreeMap::new();
    let mut fn_map: BTreeMap<String, Vec<FnKey>> = BTreeMap::new();
    for fl in files.values() {
        for f in &fl.fns {
            if !fl.in_test(f.sig_line) {
                fn_map.entry(f.name.clone()).or_default().push(fn_key(f));
            }
            let mut rec = f.clone();
            parse_callees(&mut rec);
            fn_table.insert(fn_key(f), rec);
        }
    }

    // required roots: every non-test definition of a fixed-path kernel
    // entry point must be annotated, independent of whether any other
    // root exists — this runs before the `roots.is_empty()` early return
    for name in REQUIRED_NO_ALLOC {
        let Some(keys) = fn_map.get(name) else { continue };
        for key in keys {
            let f = &fn_table[key];
            if f.no_alloc {
                continue;
            }
            let (allowed, reason) = allow_for(&files[&f.file], f.sig_line, RULE_COVERAGE);
            findings.push(Finding {
                rule: RULE_COVERAGE,
                file: f.file.clone(),
                line: f.sig_line,
                message: format!(
                    "`{name}` is a fixed-path kernel root and must carry \
                     `// apfp-lint: no_alloc`"
                ),
                allowed,
                reason,
            });
        }
    }

    let roots: Vec<FnKey> = fn_table
        .values()
        .filter(|f| f.no_alloc)
        .map(fn_key)
        .collect();

    // transitive denylist walk from every annotated root
    let mut visited: HashSet<FnKey> = HashSet::new();
    let mut queue: Vec<(FnKey, String)> = fn_table
        .values()
        .filter(|f| f.no_alloc && !f.cold)
        .map(|f| (fn_key(f), f.name.clone()))
        .collect();
    while let Some((key, root)) = queue.pop() {
        if !visited.insert(key.clone()) {
            continue;
        }
        let Some(f) = fn_table.get(&key) else { continue };
        let Some(fl) = files.get(&f.file) else { continue };
        let ctx = format!(" in `{}` (no_alloc root: `{root}`)", f.name);
        scan_denylist(fl, f.body_start_line, f.end_line, &DENY_ALLOC, RULE_ALLOC, findings, &ctx);
        for cand in resolve_callees(f, &fn_map) {
            if fn_table.get(&cand).map_or(false, |c| !c.cold) {
                queue.push((cand, root.clone()));
            }
        }
    }

    // coverage: every annotated fn must be named by tests/alloc_free.rs or
    // be reachable from an annotated fn that is
    if roots.is_empty() {
        return;
    }
    let Some(coverage_text) = coverage_text else {
        for key in &roots {
            let f = &fn_table[key];
            let line = if f.no_alloc_line > 0 { f.no_alloc_line } else { f.sig_line };
            findings.push(Finding::deny(
                RULE_COVERAGE, &f.file, line,
                format!("`{}` is marked no_alloc but tests/alloc_free.rs was not found", f.name),
            ));
        }
        return;
    };
    let mut covered: HashSet<FnKey> = HashSet::new();
    let mut queue: Vec<FnKey> = Vec::new();
    for key in &roots {
        if ident_mentioned(coverage_text, &key.2) {
            covered.insert(key.clone());
            queue.push(key.clone());
        }
    }
    let mut seen = covered.clone();
    while let Some(key) = queue.pop() {
        let Some(f) = fn_table.get(&key) else { continue };
        for cand in resolve_callees(f, &fn_map) {
            if !seen.insert(cand.clone()) {
                continue;
            }
            if fn_table.get(&cand).map_or(false, |c| c.no_alloc) {
                covered.insert(cand.clone());
            }
            queue.push(cand);
        }
    }
    for key in &roots {
        if covered.contains(key) {
            continue;
        }
        let f = &fn_table[key];
        let line = if f.no_alloc_line > 0 { f.no_alloc_line } else { f.sig_line };
        let (allowed, reason) = allow_for(&files[&f.file], line, RULE_COVERAGE);
        findings.push(Finding {
            rule: RULE_COVERAGE,
            file: f.file.clone(),
            line,
            message: format!(
                "`{}` is marked no_alloc but is not exercised by tests/alloc_free.rs",
                f.name
            ),
            allowed,
            reason,
        });
    }
}

// ---------------------------------------------------------------------------
// Rule: panic
// ---------------------------------------------------------------------------

fn in_panic_scope(rel: &str) -> bool {
    PANIC_SCOPE.iter().any(|p| rel == *p || rel.starts_with(p))
}

fn run_panic_rule(fl: &FileLint, findings: &mut Vec<Finding>) {
    scan_denylist(fl, 1, fl.lines.len(), &DENY_PANIC, RULE_PANIC, findings, " in non-test code");
}

// ---------------------------------------------------------------------------
// Rule: index
// ---------------------------------------------------------------------------

/// (line, content) for subscript expressions `expr[...]`.
fn subscript_sites(fl: &FileLint) -> Vec<(usize, String)> {
    let masked = &fl.masked;
    let n = masked.len();
    let mut sites = Vec::new();
    let mut i = 0;
    while i < n {
        if masked[i] != b'[' {
            i += 1;
            continue;
        }
        let mut k = i as isize - 1;
        while k >= 0 && (masked[k as usize] == b' ' || masked[k as usize] == b'\t') {
            k -= 1;
        }
        let prev = if k >= 0 { masked[k as usize] } else { b' ' };
        if !(is_ident(prev) || prev == b')' || prev == b']') {
            i += 1;
            continue;
        }
        if is_ident(prev) {
            // a keyword before `[` means a pattern or literal, not a subscript
            let mut w = k;
            while w >= 0 && is_ident(masked[w as usize]) {
                w -= 1;
            }
            let word = &masked[(w + 1) as usize..=k as usize];
            if matches!(word, b"let" | b"else" | b"in" | b"return" | b"mut" | b"ref" | b"match") {
                i += 1;
                continue;
            }
        }
        let mut depth = 0i32;
        let mut e = i;
        while e < n {
            if masked[e] == b'[' {
                depth += 1;
            } else if masked[e] == b']' {
                depth -= 1;
                if depth == 0 {
                    break;
                }
            }
            e += 1;
        }
        let content = String::from_utf8_lossy(&masked[i + 1..e.min(n)]).into_owned();
        sites.push((fl.line_of(i), content));
        i = e + 1;
    }
    sites
}

/// (guardable idents, any_ident): field accesses, constants and numeric
/// types are opaque to the guard heuristic and excluded from the first
/// list; `any_ident` distinguishes them from pure-literal subscripts.
fn subscript_idents(content: &str) -> (Vec<String>, bool) {
    let bytes = content.as_bytes();
    let n = bytes.len();
    let mut idents: Vec<String> = Vec::new();
    let mut any_ident = false;
    let mut i = 0;
    while i < n {
        if is_ident(bytes[i]) && !bytes[i].is_ascii_digit() && (i == 0 || !is_ident(bytes[i - 1])) {
            let mut j = i;
            while j < n && is_ident(bytes[j]) {
                j += 1;
            }
            let name = String::from_utf8_lossy(&bytes[i..j]).into_owned();
            let mut k = i as isize - 1;
            while k >= 0 && (bytes[k as usize] == b' ' || bytes[k as usize] == b'\t') {
                k -= 1;
            }
            let is_field = k >= 0 && bytes[k as usize] == b'.';
            // `x.field` as an index is opaque to the guard heuristic: skip
            // both the base and the field (covered by the dynamic tests)
            let mut nk = j;
            while nk < n && (bytes[nk] == b' ' || bytes[nk] == b'\t') {
                nk += 1;
            }
            let is_base = nk < n && bytes[nk] == b'.';
            if name != "as" {
                any_ident = true;
            }
            let skip = is_field
                || is_base
                || INDEX_IDENT_SKIP.contains(&name.as_str())
                || name.as_bytes()[0].is_ascii_uppercase();
            if !skip && !idents.contains(&name) {
                idents.push(name);
            }
            i = j;
        } else {
            i += 1;
        }
    }
    (idents, any_ident)
}

fn run_index_rule(fl: &FileLint, findings: &mut Vec<Finding>) {
    let mut seen: HashSet<(usize, Vec<String>)> = HashSet::new();
    for (lineno, content) in subscript_sites(fl) {
        if fl.in_test(lineno) {
            continue;
        }
        if content.contains("..") {
            continue; // range slices pair with copy_from_slice length asserts
        }
        let (idents, any_ident) = subscript_idents(&content);
        let encl = fl.enclosing_fns(lineno);
        let Some(&fn_idx) = encl.iter().min_by_key(|&&i| fl.fns[i].sig_line) else {
            continue;
        };
        let fnr = &fl.fns[fn_idx];
        let mut unguarded: Vec<String> = Vec::new();
        if idents.is_empty() && !any_ident {
            unguarded.push("<literal>".to_string());
        }
        for ident in &idents {
            let mut ok = false;
            for ln in fnr.sig_line..=lineno {
                let Some(line) = fl.masked_lines.get(ln - 1) else { break };
                if ident_mentioned(line, ident) && GUARD_MARKS.iter().any(|m| line.contains(m)) {
                    ok = true;
                    break;
                }
            }
            if !ok {
                unguarded.push(ident.clone());
            }
        }
        if unguarded.is_empty() {
            continue;
        }
        if !seen.insert((lineno, unguarded.clone())) {
            continue;
        }
        let (allowed, reason) = allow_for(fl, lineno, RULE_INDEX);
        let what = unguarded
            .iter()
            .map(|u| format!("`{u}`"))
            .collect::<Vec<_>>()
            .join(", ");
        findings.push(Finding {
            rule: RULE_INDEX,
            file: fl.rel.clone(),
            line: lineno,
            message: format!("subscript without visible guard for {what}"),
            allowed,
            reason,
        });
    }
}

// ---------------------------------------------------------------------------
// Rule: hazard
// ---------------------------------------------------------------------------

fn in_hazard_scope(rel: &str) -> bool {
    HAZARD_SCOPE.iter().any(|p| rel == *p || rel.ends_with(p))
}

/// Scan braced `token { ... }` literals for the fields the reply protocol
/// rides on: `c_buf` (the staging buffer returns on every arm) and
/// `attempt` (the delivery counter the retry arm keys on).  Declarations
/// (`struct`/`enum`/`impl` heads, return types) are skipped; destructuring
/// patterns that elide fields with `..` are exempt from the `attempt`
/// requirement (the rest pattern already carries it).
fn scan_reply_literals(fl: &FileLint, token: &str, findings: &mut Vec<Finding>) {
    let masked = &fl.masked;
    let n = masked.len();
    let mut i = 0;
    while let Some(at) = memfind(masked, token.as_bytes(), i) {
        i = at;
        let before = if i > 0 { masked[i - 1] } else { b' ' };
        if is_ident(before) {
            i += token.len();
            continue;
        }
        let head = String::from_utf8_lossy(&masked[i.saturating_sub(16)..i]).into_owned();
        let mut j = i + token.len();
        while j < n && masked[j].is_ascii_whitespace() {
            j += 1;
        }
        if j >= n
            || masked[j] != b'{'
            || ["struct", "impl", "enum", "->"].iter().any(|k| head.contains(k))
        {
            i += token.len();
            continue;
        }
        let mut depth = 0i32;
        let mut e = j;
        while e < n {
            if masked[e] == b'{' {
                depth += 1;
            } else if masked[e] == b'}' {
                depth -= 1;
                if depth == 0 {
                    break;
                }
            }
            e += 1;
        }
        let lineno = fl.line_of(i);
        let body = &masked[j..e.min(n)];
        if !fl.in_test(lineno) {
            if memfind(body, b"c_buf", 0).is_none() {
                let (allowed, reason) = allow_for(fl, lineno, RULE_HAZARD);
                findings.push(Finding {
                    rule: RULE_HAZARD,
                    file: fl.rel.clone(),
                    line: lineno,
                    message: format!(
                        "`{token}` literal without `c_buf`: the staging buffer must \
                         ride every job and reply arm"
                    ),
                    allowed,
                    reason,
                });
            } else if memfind(body, b"..", 0).is_none()
                && memfind(body, b"attempt", 0).is_none()
            {
                let (allowed, reason) = allow_for(fl, lineno, RULE_HAZARD);
                findings.push(Finding {
                    rule: RULE_HAZARD,
                    file: fl.rel.clone(),
                    line: lineno,
                    message: format!(
                        "`{token}` literal without `attempt`: the delivery counter \
                         the retry budget keys on must ride every job and reply"
                    ),
                    allowed,
                    reason,
                });
            }
        }
        i = e;
    }
}

/// The mixed-width launch path must validate widths before touching any
/// hazard or dispatch state: inside `fn enqueue_gemm_at`, the typed
/// `WidthMismatch` rejection has to appear before the first hazard-state
/// token (`writes_our_set`, `retire_n`, `build_b_cache`).  A launch
/// rejected only after the hazard drain would have retired other
/// launches — mutated stream state — for a launch that never runs.
fn scan_width_agreement(fl: &FileLint, findings: &mut Vec<Finding>) {
    const FN_TOKEN: &[u8] = b"fn enqueue_gemm_at";
    const FN_ENDS: [&[u8]; 4] = [b"\nfn ", b"\npub fn ", b"\n    fn ", b"\n    pub fn "];
    const HAZARD_TOKENS: [&[u8]; 3] = [b"writes_our_set", b"retire_n", b"build_b_cache"];
    let masked = &fl.masked;
    let mut i = 0;
    while let Some(at) = memfind(masked, FN_TOKEN, i) {
        i = at + FN_TOKEN.len();
        let lineno = fl.line_of(at);
        if fl.in_test(lineno) {
            continue;
        }
        let end = FN_ENDS
            .iter()
            .filter_map(|t| memfind(masked, t, i))
            .min()
            .unwrap_or(masked.len());
        let body = &masked[i..end];
        let check = memfind(body, b"WidthMismatch", 0);
        let hazard = HAZARD_TOKENS.iter().filter_map(|t| memfind(body, t, 0)).min();
        let bad = match (check, hazard) {
            (None, _) => true,
            (Some(c), Some(h)) => h < c,
            (Some(_), None) => false,
        };
        if bad {
            let (allowed, reason) = allow_for(fl, lineno, RULE_HAZARD);
            findings.push(Finding {
                rule: RULE_HAZARD,
                file: fl.rel.clone(),
                line: lineno,
                message: "`enqueue_gemm_at` must reject mismatched operand widths \
                          (`WidthMismatch`) before the hazard scan touches stream state"
                    .to_string(),
                allowed,
                reason,
            });
        }
        i = end;
    }
}

fn run_hazard_rule(fl: &FileLint, findings: &mut Vec<Finding>) {
    // every TileResult reply and Job::GemmTile job must carry the staging
    // buffer and the delivery-attempt counter (ISSUE 7's retry arm)
    scan_reply_literals(fl, "TileResult", findings);
    scan_reply_literals(fl, "GemmTile", findings);
    if !fl.rel.ends_with("stream.rs") {
        return;
    }
    // mixed-width launches: the width-agreement check precedes the hazard
    // scan (ISSUE 10)
    scan_width_agreement(fl, findings);

    // leader-side receives must be recv_timeout (hang-proof drains)
    for (idx, line) in fl.masked_lines.iter().enumerate() {
        let lineno = idx + 1;
        if fl.in_test(lineno) {
            continue;
        }
        if !find_with_boundary(line, ".recv()").is_empty() {
            let (allowed, reason) = allow_for(fl, lineno, RULE_HAZARD);
            findings.push(Finding {
                rule: RULE_HAZARD,
                file: fl.rel.clone(),
                line: lineno,
                message: "bare `.recv()` on a reply channel: use `recv_timeout` so a \
                          dead worker cannot hang the leader"
                    .to_string(),
                allowed,
                reason,
            });
        }
        for k in find_with_boundary(line, "channel(") {
            if line[..k].ends_with("sync_") {
                continue;
            }
            let (allowed, reason) = allow_for(fl, lineno, RULE_HAZARD);
            findings.push(Finding {
                rule: RULE_HAZARD,
                file: fl.rel.clone(),
                line: lineno,
                message: "unbounded `channel()`: reply channels must be bounded \
                          `sync_channel` sized to the launch"
                    .to_string(),
                allowed,
                reason,
            });
        }
        if ident_mentioned(line, "Inflight") {
            let (allowed, reason) = allow_for(fl, lineno, RULE_HAZARD);
            findings.push(Finding {
                rule: RULE_HAZARD,
                file: fl.rel.clone(),
                line: lineno,
                message: "shared `Inflight` channel type: per-launch reply channels \
                          replaced it (PR 5)"
                    .to_string(),
                allowed,
                reason,
            });
        }
        if ident_mentioned(line, "REPLY_LIVENESS_INTERVAL") {
            let (allowed, reason) = allow_for(fl, lineno, RULE_HAZARD);
            findings.push(Finding {
                rule: RULE_HAZARD,
                file: fl.rel.clone(),
                line: lineno,
                message: "hardcoded `REPLY_LIVENESS_INTERVAL`: the probe interval is \
                          `ApfpConfig::reply_timeout` now (ISSUE 7)"
                    .to_string(),
                allowed,
                reason,
            });
        }
    }
}

// ---------------------------------------------------------------------------
// Driver
// ---------------------------------------------------------------------------

pub struct Summary {
    pub files: usize,
    pub findings: usize,
    pub denied: usize,
    pub allowed: usize,
}

pub struct Report {
    pub summary: Summary,
    pub findings: Vec<Finding>,
}

fn load_file(root: &Path, path: &Path, findings: &mut Vec<Finding>) -> std::io::Result<FileLint> {
    let rel = path
        .strip_prefix(root)
        .unwrap_or(path)
        .components()
        .map(|c| c.as_os_str().to_string_lossy().into_owned())
        .collect::<Vec<_>>()
        .join("/");
    let src = std::fs::read(path)?;
    let masked = mask_source(&src);
    let mut line_starts = vec![0usize];
    for (idx, &ch) in src.iter().enumerate() {
        if ch == b'\n' {
            line_starts.push(idx + 1);
        }
    }
    let text = String::from_utf8_lossy(&src).into_owned();
    let masked_text = String::from_utf8_lossy(&masked).into_owned();
    let mut fl = FileLint {
        rel: rel.clone(),
        masked,
        line_starts,
        lines: text.split('\n').map(str::to_string).collect(),
        masked_lines: masked_text.split('\n').map(str::to_string).collect(),
        site_allows: BTreeMap::new(),
        fns: Vec::new(),
        test_ranges: Vec::new(),
    };
    let anns = parse_annotations(&fl.lines, findings, &rel);
    parse_fns(&mut fl);
    parse_test_ranges(&mut fl);
    attach_annotations(&mut fl, &anns, findings);
    Ok(fl)
}

fn collect_rs_files(dir: &Path, out: &mut Vec<std::path::PathBuf>) -> std::io::Result<()> {
    for entry in std::fs::read_dir(dir)? {
        let path = entry?.path();
        if path.is_dir() {
            collect_rs_files(&path, out)?;
        } else if path.extension().map_or(false, |e| e == "rs") {
            out.push(path);
        }
    }
    Ok(())
}

pub fn lint_root(src_root: &Path, coverage_path: Option<&Path>) -> std::io::Result<Report> {
    let default_cov = src_root
        .parent()
        .map(|p| p.join("tests").join("alloc_free.rs"))
        .filter(|p| p.exists());
    let coverage_text = match coverage_path {
        Some(p) => Some(std::fs::read_to_string(p)?),
        None => match default_cov {
            Some(p) => Some(std::fs::read_to_string(p)?),
            None => None,
        },
    };

    let mut findings: Vec<Finding> = Vec::new();
    let mut paths = Vec::new();
    collect_rs_files(src_root, &mut paths)?;
    paths.sort();
    let mut files: BTreeMap<String, FileLint> = BTreeMap::new();
    for path in &paths {
        let fl = load_file(src_root, path, &mut findings)?;
        files.insert(fl.rel.clone(), fl);
    }

    run_alloc_rule(&files, coverage_text.as_deref(), &mut findings);
    for fl in files.values() {
        if in_panic_scope(&fl.rel) {
            run_panic_rule(fl, &mut findings);
            run_index_rule(fl, &mut findings);
        }
        if in_hazard_scope(&fl.rel) {
            run_hazard_rule(fl, &mut findings);
        }
    }

    let mut uniq: BTreeMap<(String, usize, &'static str, String), Finding> = BTreeMap::new();
    for f in findings {
        uniq.entry(f.key()).or_insert(f);
    }
    let ordered: Vec<Finding> = uniq.into_values().collect();
    let denied = ordered.iter().filter(|f| !f.allowed).count();
    Ok(Report {
        summary: Summary {
            files: files.len(),
            findings: ordered.len(),
            denied,
            allowed: ordered.len() - denied,
        },
        findings: ordered,
    })
}

fn json_escape(s: &str) -> String {
    let mut out = String::with_capacity(s.len() + 2);
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\t' => out.push_str("\\t"),
            '\r' => out.push_str("\\r"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out
}

pub fn render_json(report: &Report) -> String {
    let mut out = String::new();
    let s = &report.summary;
    let _ = write!(
        out,
        "{{\n  \"summary\": {{\n    \"files\": {},\n    \"findings\": {},\n    \
         \"denied\": {},\n    \"allowed\": {}\n  }},\n  \"findings\": [",
        s.files, s.findings, s.denied, s.allowed
    );
    for (i, f) in report.findings.iter().enumerate() {
        let reason = match &f.reason {
            Some(r) => format!("\"{}\"", json_escape(r)),
            None => "null".to_string(),
        };
        let _ = write!(
            out,
            "{}\n    {{\n      \"rule\": \"{}\",\n      \"file\": \"{}\",\n      \
             \"line\": {},\n      \"message\": \"{}\",\n      \"allowed\": {},\n      \
             \"reason\": {}\n    }}",
            if i == 0 { "" } else { "," },
            json_escape(f.rule),
            json_escape(&f.file),
            f.line,
            json_escape(&f.message),
            f.allowed,
            reason
        );
    }
    if report.findings.is_empty() {
        out.push_str("]\n}");
    } else {
        out.push_str("\n  ]\n}");
    }
    out
}

pub fn render_human(report: &Report) -> String {
    let mut out: Vec<String> = Vec::new();
    for f in &report.findings {
        let mark = if f.allowed { "allow" } else { "DENY " };
        out.push(format!("{mark} {}:{}: [{}] {}", f.file, f.line, f.rule, f.message));
        if f.allowed {
            if let Some(reason) = f.reason.as_deref().filter(|r| !r.is_empty()) {
                out.push(format!("      = reason: {reason}"));
            }
        }
    }
    let s = &report.summary;
    out.push(format!(
        "{} findings across {} files: {} denied, {} allowed",
        s.findings, s.files, s.denied, s.allowed
    ));
    out.join("\n")
}
