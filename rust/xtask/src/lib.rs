//! Repo automation for the apfp crate.  The only task today is the
//! apfp-lint static-analysis pass; the engine lives in a library so the
//! integration tests in `tests/fixtures.rs` can drive it directly.

pub mod engine;
