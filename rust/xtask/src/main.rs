//! `cargo xtask lint` — run the apfp-lint static-analysis pass.
//!
//! Usage (via the alias in `.cargo/config.toml`):
//!
//! ```text
//! cargo xtask lint                       # lint rust/src, human output
//! cargo xtask lint --format json         # machine-readable report
//! cargo xtask lint --src path/to/src     # lint another tree (fixtures)
//! cargo xtask lint --coverage path.rs    # explicit alloc_free.rs
//! ```
//!
//! Exit status is 1 when any finding is denied (no matching
//! `// apfp-lint: allow(...)`), so CI can gate on it directly.

use std::path::PathBuf;
use std::process::ExitCode;

use xtask::engine;

fn usage() -> ! {
    eprintln!(
        "usage: cargo xtask lint [--src PATH] [--coverage PATH] [--format human|json]"
    );
    std::process::exit(2);
}

fn main() -> ExitCode {
    let mut argv = std::env::args().skip(1);
    match argv.next().as_deref() {
        Some("lint") => {}
        _ => usage(),
    }

    let mut src: Option<PathBuf> = None;
    let mut coverage: Option<PathBuf> = None;
    let mut format = String::from("human");
    while let Some(arg) = argv.next() {
        match arg.as_str() {
            "--src" => src = Some(PathBuf::from(argv.next().unwrap_or_else(|| usage()))),
            "--coverage" => {
                coverage = Some(PathBuf::from(argv.next().unwrap_or_else(|| usage())))
            }
            "--format" => format = argv.next().unwrap_or_else(|| usage()),
            _ => usage(),
        }
    }
    if format != "human" && format != "json" {
        usage();
    }

    // xtask lives at rust/xtask; the crate under lint is rust/src.
    let src = src.unwrap_or_else(|| {
        PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("..").join("src")
    });

    let report = match engine::lint_root(&src, coverage.as_deref()) {
        Ok(report) => report,
        Err(err) => {
            eprintln!("apfp-lint: cannot lint {}: {err}", src.display());
            return ExitCode::from(2);
        }
    };
    if format == "json" {
        println!("{}", engine::render_json(&report));
    } else {
        println!("{}", engine::render_human(&report));
    }
    if report.summary.denied > 0 {
        ExitCode::from(1)
    } else {
        ExitCode::SUCCESS
    }
}
