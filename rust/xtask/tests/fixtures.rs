//! Fixture-driven tests for the apfp-lint engine.
//!
//! Each directory under `tests/fixtures/` is a miniature crate (`src/`
//! tree plus an optional `tests/alloc_free.rs`) with an `expected.txt`
//! listing the findings the engine must produce, one per line:
//!
//! ```text
//! rule<TAB>file<TAB>line<TAB>denied|allowed
//! ```
//!
//! The same fixtures pin the Python port (python/tests/test_apfp_lint.py),
//! so the two engines cannot drift apart silently.  Messages are not part
//! of the contract — only (rule, file, line, status) rows are compared.

use std::collections::BTreeSet;
use std::path::{Path, PathBuf};

use xtask::engine;

fn fixtures_dir() -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("tests").join("fixtures")
}

fn findings_as_rows(report: &engine::Report) -> Vec<String> {
    let mut rows: Vec<String> = report
        .findings
        .iter()
        .map(|f| {
            let status = if f.allowed { "allowed" } else { "denied" };
            format!("{}\t{}\t{}\t{}", f.rule, f.file, f.line, status)
        })
        .collect();
    rows.sort();
    rows
}

fn expected_rows(path: &Path) -> Vec<String> {
    let text = std::fs::read_to_string(path)
        .unwrap_or_else(|e| panic!("read {}: {e}", path.display()));
    let mut rows: Vec<String> = text
        .lines()
        .map(str::trim_end)
        .filter(|l| !l.is_empty())
        .map(str::to_string)
        .collect();
    rows.sort();
    rows
}

fn run_fixture(name: &str) {
    let dir = fixtures_dir().join(name);
    let report = engine::lint_root(&dir.join("src"), None)
        .unwrap_or_else(|e| panic!("lint fixture {name}: {e}"));
    let got = findings_as_rows(&report);
    let want = expected_rows(&dir.join("expected.txt"));
    assert_eq!(got, want, "fixture `{name}` rows diverge from expected.txt");
}

#[test]
fn fixture_clean() {
    run_fixture("clean");
}

#[test]
fn fixture_alloc_bad() {
    run_fixture("alloc_bad");
}

#[test]
fn fixture_alloc_allow() {
    run_fixture("alloc_allow");
}

#[test]
fn fixture_coverage_bad() {
    run_fixture("coverage_bad");
}

#[test]
fn fixture_coverage_required_bad() {
    run_fixture("coverage_required_bad");
}

#[test]
fn fixture_panic_bad() {
    run_fixture("panic_bad");
}

#[test]
fn fixture_index_bad() {
    run_fixture("index_bad");
}

#[test]
fn fixture_hazard_bad() {
    run_fixture("hazard_bad");
}

#[test]
fn fixture_annotation_bad() {
    run_fixture("annotation_bad");
}

/// The bad fixtures must collectively prove every rule can fire.
#[test]
fn fixture_set_exercises_every_rule() {
    let mut denied: BTreeSet<String> = BTreeSet::new();
    for entry in std::fs::read_dir(fixtures_dir()).expect("fixtures dir") {
        let dir = entry.expect("fixture entry").path();
        if !dir.is_dir() {
            continue;
        }
        let report = engine::lint_root(&dir.join("src"), None)
            .unwrap_or_else(|e| panic!("lint {}: {e}", dir.display()));
        for f in report.findings.iter().filter(|f| !f.allowed) {
            denied.insert(f.rule.to_string());
        }
    }
    let mut want: BTreeSet<String> =
        engine::KNOWN_RULES.iter().map(|r| r.to_string()).collect();
    want.insert("annotation".to_string());
    assert_eq!(denied, want, "every rule needs a bad fixture that trips it");
}

/// The crate's own source must be clean: zero denied findings, and every
/// allowed finding must carry a non-empty reason.
#[test]
fn live_tree_is_clean() {
    let src = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("..").join("src");
    let report = engine::lint_root(&src, None).expect("lint rust/src");
    let denied: Vec<&engine::Finding> =
        report.findings.iter().filter(|f| !f.allowed).collect();
    assert!(
        denied.is_empty(),
        "rust/src has denied lint findings:\n{}",
        engine::render_human(&report)
    );
    for f in &report.findings {
        assert!(
            f.reason.as_deref().map_or(false, |r| !r.trim().is_empty()),
            "allowed finding without a reason at {}:{}",
            f.file,
            f.line
        );
    }
}

/// JSON output must round-trip the deny count (spot check against the
/// panic_bad fixture: three denied findings in runtime/mod.rs plus two
/// in runtime/sim_backend.rs, proving the sim backend's path is in
/// scope).
#[test]
fn json_rendering_reports_denials() {
    let dir = fixtures_dir().join("panic_bad");
    let report = engine::lint_root(&dir.join("src"), None).expect("lint panic_bad");
    assert_eq!(report.summary.denied, 5);
    let json = engine::render_json(&report);
    assert!(json.contains("\"denied\": 5"), "summary missing from JSON:\n{json}");
    assert!(json.contains("\"rule\": \"panic\""), "findings missing from JSON:\n{json}");
}
