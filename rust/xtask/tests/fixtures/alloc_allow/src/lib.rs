// apfp-lint: allow(alloc, scope=fn, reason="cold constructor: runs once at startup")
fn build_pool() -> Vec<u64> {
    Vec::with_capacity(64)
}

// apfp-lint: no_alloc
pub fn kernel_into(out: &mut Vec<u64>) {
    out.clear();
    // apfp-lint: allow(alloc, reason="capacity reuse: resize refills the cleared buffer")
    out.resize(8, 0);
    let _ = build_pool().len(); // cold callee: traversal stops at the fn-scope allow
}
