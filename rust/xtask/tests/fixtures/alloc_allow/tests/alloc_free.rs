// kernel_into runs under the counting allocator
