fn helper(n: usize) -> Vec<u64> {
    let v = vec![0u64; n];
    v
}

// apfp-lint: no_alloc
pub fn kernel_into(out: &mut Vec<u64>) {
    out.extend_from_slice(&helper(4));
    let s = String::from("scratch");
    let _ = s;
}
