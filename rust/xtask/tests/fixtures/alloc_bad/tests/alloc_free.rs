// kernel_into is exercised here
