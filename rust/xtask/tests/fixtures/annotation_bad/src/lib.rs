// apfp-lint: allow(alloc
pub fn a() {}

// apfp-lint: allow(frobnicate, reason="no such rule")
pub fn b() {}

// apfp-lint: allow(alloc)
pub fn c() {}

// apfp-lint: nonsense directive
pub fn d() {}

pub fn e() {}
// apfp-lint: no_alloc
