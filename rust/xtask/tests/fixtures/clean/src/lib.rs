//! A well-behaved crate: annotated kernels allocate nothing, guarded
//! indexing only, no panics in scoped paths.

// apfp-lint: no_alloc
pub fn axpy_into(a: &[u64], b: &[u64], out: &mut [u64]) {
    for i in 0..out.len().min(a.len()).min(b.len()) {
        out[i] = a[i].wrapping_add(b[i]);
    }
}
