pub fn checked(v: &[u64]) -> Option<u64> {
    v.first().copied()
}
