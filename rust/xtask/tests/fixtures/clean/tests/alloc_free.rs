// exercises axpy_into in a counting-allocator loop
#[test]
fn axpy_into_is_alloc_free() {}
