// apfp-lint: no_alloc
pub fn proven_into(out: &mut [u64]) {
    if let Some(x) = out.first_mut() {
        *x = 1;
    }
}

// apfp-lint: no_alloc
pub fn unproven_into(out: &mut [u64]) {
    if let Some(x) = out.first_mut() {
        *x = 2;
    }
}
