// only proven_into appears here
