//! A fixed-path kernel root that lost its `no_alloc` annotation: the
//! required-roots check must flag it even though no other annotated
//! function exists in the tree.

pub fn gemm_fixed(a: &[u64], c: &mut [u64]) {
    for (x, y) in a.iter().zip(c.iter_mut()) {
        *y = y.wrapping_add(*x);
    }
}

pub fn unrelated_helper(x: u64) -> u64 {
    x ^ 1
}
