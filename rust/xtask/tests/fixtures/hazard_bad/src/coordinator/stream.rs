use std::sync::mpsc::channel;

pub struct TileResult {
    pub c_buf: u64,
    pub err: Option<String>,
}

pub struct Inflight {
    pub id: u64,
}

const REPLY_LIVENESS_INTERVAL: u64 = 250;

pub fn drain(rx: &std::sync::mpsc::Receiver<TileResult>) -> Option<TileResult> {
    let r = rx.recv().ok();
    let (_tx, _rx2) = channel();
    let _ = _tx.send(0u64);
    drop(_rx2);
    r
}

pub struct WidthMismatch;

pub fn enqueue_gemm_at(bits: u32, widths: &[u32]) -> bool {
    // hazard state is touched before the widths are validated: the drain
    // below retires launches on behalf of a launch that may never run
    let writes_our_set = bits != 0;
    if writes_our_set {
        retire_n(1);
    }
    if !widths.contains(&bits) {
        let _rejected = WidthMismatch;
        return false;
    }
    true
}

fn retire_n(_n: usize) {}
