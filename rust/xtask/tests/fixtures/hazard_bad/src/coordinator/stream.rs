use std::sync::mpsc::channel;

pub struct TileResult {
    pub c_buf: u64,
    pub err: Option<String>,
}

pub struct Inflight {
    pub id: u64,
}

const REPLY_LIVENESS_INTERVAL: u64 = 250;

pub fn drain(rx: &std::sync::mpsc::Receiver<TileResult>) -> Option<TileResult> {
    let r = rx.recv().ok();
    let (_tx, _rx2) = channel();
    let _ = _tx.send(0u64);
    drop(_rx2);
    r
}
