use super::stream::TileResult;

pub fn reply_ok(c_buf: u64) -> TileResult {
    TileResult { c_buf, err: None }
}

pub fn reply_bad() -> TileResult {
    TileResult { err: None }
}

pub enum Job {
    GemmTile { c_buf: u64, attempt: u32 },
}

pub fn job_bad() -> Job {
    Job::GemmTile { c_buf: 7 }
}

pub fn job_elided(j: Job) -> u64 {
    match j {
        Job::GemmTile { c_buf, .. } => c_buf,
    }
}
