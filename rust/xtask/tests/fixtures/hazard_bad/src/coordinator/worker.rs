use super::stream::TileResult;

pub fn reply_ok(c_buf: u64) -> TileResult {
    TileResult { c_buf, err: None }
}

pub fn reply_bad() -> TileResult {
    TileResult { err: None }
}
