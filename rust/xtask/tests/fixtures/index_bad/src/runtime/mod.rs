const LANES: usize = 4;

pub fn pick(v: &[u64], i: usize) -> u64 {
    v[i]
}

pub fn sum(v: &[u64]) -> u64 {
    let mut acc = 0;
    for i in 0..v.len() {
        acc += v[i];
    }
    acc
}

pub fn lane(v: &[u64; 8]) -> u64 {
    v[LANES]
}
