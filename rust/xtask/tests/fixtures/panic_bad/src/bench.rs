pub fn bench_only(v: &[u64]) -> u64 {
    *v.first().unwrap()
}
