pub fn risky(v: &[u64]) -> u64 {
    let first = v.first().unwrap();
    let second: u64 = "2".parse().expect("parses");
    if *first > second {
        panic!("bad ordering");
    }
    *first
}

#[cfg(test)]
mod tests {
    #[test]
    fn fine_here() {
        let v = vec![1u64];
        let _ = v.first().unwrap();
    }
}
