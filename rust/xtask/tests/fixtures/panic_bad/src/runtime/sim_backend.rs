pub fn modeled(costs: &[u64]) -> u64 {
    let last = costs.last().unwrap();
    if *last == 0 {
        panic!("empty model ledger");
    }
    *last
}
